"""End-to-end driver: train a ~100M-param dense LM for a few hundred steps.

Uses the full production stack — pjit train step (grad accumulation, AdamW),
deterministic bigram data pipeline, checkpointing, fault-tolerant runner with
an injected node failure at step 120 (recovery is exact) — on a 1x1 CPU mesh.

    PYTHONPATH=src python examples/train_lm.py [--steps 300]
"""

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig, TrainConfig
from repro.checkpoint import CheckpointManager
from repro.data import SyntheticLM
from repro.launch.mesh import compat_mesh
from repro.launch.steps import build_train_step
from repro.models import api
from repro.optim import init_opt_state
from repro.runtime import TrainingRunner, FaultInjector, StragglerDetector

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=300)
ap.add_argument("--ckpt", default="/tmp/repro_example_train")
args = ap.parse_args()

# ~100M params: 12 layers x d_model 768 (GPT-2-small-class), vocab 32k
cfg = ModelConfig(name="lm-100m", family="dense", n_layers=12, d_model=768,
                  n_heads=12, n_kv_heads=4, d_ff=2048, vocab_size=32_000,
                  mlp="swiglu", remat="none", dtype="float32")
print(f"params: {cfg.param_count() / 1e6:.1f}M")

BATCH, SEQ = 8, 128
mesh = compat_mesh(jax.devices()[:1], (1, 1), ("data", "model"))
tcfg = TrainConfig(lr=3e-3, warmup_steps=30, total_steps=args.steps,
                   grad_accum=1, zero1=False)
built = build_train_step(cfg, ShapeConfig("ex", SEQ, BATCH, "train"),
                         mesh, tcfg)
step = jax.jit(built.fn, in_shardings=built.in_shardings,
               out_shardings=built.out_shardings, donate_argnums=(0,))

params = api.init_params(cfg, jax.random.PRNGKey(0))
state = {"params": params, "opt": init_opt_state(params, tcfg, master=False)}
data = SyntheticLM(cfg, batch=BATCH, seq=SEQ, seed=0, branching=4,
                   vocab_limit=256)

losses = []
t0 = time.time()


def on_metrics(s, m):
    losses.append(float(m["loss"]))
    if s % 20 == 0:
        print(f"step {s:4d} loss {losses[-1]:.4f} "
              f"({(time.time()-t0)/max(len(losses),1):.2f}s/step)", flush=True)


def step_fn(state, batch):
    with mesh:
        return step(state, {k: jnp.asarray(v) for k, v in batch.items()})


runner = TrainingRunner(step_fn, data,
                        CheckpointManager(args.ckpt, every=50, keep=2),
                        straggler=StragglerDetector(),
                        fault_injector=FaultInjector((120,)))
state, end = runner.run(state, 0, args.steps, on_metrics=on_metrics)

first, last = np.mean(losses[:20]), np.mean(losses[-20:])
print(f"\ndone: steps={end} restarts={runner.restarts} "
      f"loss {first:.3f} -> {last:.3f}")
assert last < first - 0.5, "loss should drop substantially on the bigram task"
print("loss decreased through an injected node failure — FT path exercised.")
