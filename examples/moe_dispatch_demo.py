"""Ports-as-experts: the Medusa collective schedule for MoE dispatch.

Runs an expert-parallel dispatch on 8 host devices two ways — XLA's
monolithic all-to-all ("crossbar") and N-1 ring rotations (the paper's
diagonal schedule, §III-A, on chips) — and verifies identical results.

    python examples/moe_dispatch_demo.py     (re-executes itself with 8 devices)
"""

import os
import subprocess
import sys

if os.environ.get("_MOE_DEMO_CHILD") != "1":
    env = dict(os.environ, _MOE_DEMO_CHILD="1",
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               PYTHONPATH=os.path.join(os.path.dirname(__file__), "..", "src"))
    sys.exit(subprocess.call([sys.executable, __file__], env=env))

import jax                                     # noqa: E402
import jax.numpy as jnp                        # noqa: E402
import numpy as np                             # noqa: E402
from jax.sharding import PartitionSpec as P    # noqa: E402

from repro.launch.mesh import compat_shard_map, make_mesh  # noqa: E402
from repro.parallel.collectives import ring_all_to_all, xla_all_to_all  # noqa: E402

E = jax.device_count()                         # experts = devices = ports
CAP, D = 16, 64
mesh = make_mesh((E,), ("expert",))
print(f"{E} experts on {E} devices; capacity {CAP} tokens x d={D}")

# every rank holds one CAP-token block per destination expert:
# local view [E(block per peer), CAP, D]
tokens = jax.random.normal(jax.random.PRNGKey(0), (E * E, CAP, D))

ring = jax.jit(compat_shard_map(lambda t: ring_all_to_all(t, "expert"),
                             mesh=mesh, in_specs=P("expert"),
                             out_specs=P("expert")))
xla = jax.jit(compat_shard_map(lambda t: xla_all_to_all(t, "expert"),
                            mesh=mesh, in_specs=P("expert"),
                            out_specs=P("expert")))

a, b = np.asarray(ring(tokens)), np.asarray(xla(tokens))
assert np.allclose(a, b)
print("ring schedule (N-1 ppermute rotations) == XLA all-to-all ✓")

txt = jax.jit(compat_shard_map(lambda t: ring_all_to_all(t, "expert"),
                            mesh=mesh, in_specs=P("expert"),
                            out_specs=P("expert"))).lower(tokens).compile().as_text()
n_perm = txt.count(" collective-permute(") + txt.count(" collective-permute-start(")
print(f"lowered HLO uses {n_perm} collective-permutes (= N-1 = {E-1} "
      f"diagonal steps, paper §III-A on the chip fabric)")
