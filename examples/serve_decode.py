"""Serving example: batched prefill + decode through the Medusa KV path.

Generates with all three interconnect fabrics and checks they emit identical
tokens (the paper's drop-in-replacement claim, §III-F), then reports decode
throughput per fabric.

    PYTHONPATH=src python examples/serve_decode.py
"""

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke
from repro.data import SyntheticLM
from repro.models import api

BASE = get_smoke("gemma3-12b")           # hybrid local:global — both cache kinds
BATCH, PROMPT, GEN = 4, 24, 24

data = SyntheticLM(BASE, batch=BATCH, seq=PROMPT)
prompt = jnp.asarray(data.batch_at(0)["tokens"])
params = api.init_params(BASE, jax.random.PRNGKey(0))

outs = {}
for layout in ("oracle", "crossbar", "medusa"):
    cfg = dataclasses.replace(BASE, kv_layout=layout)
    t0 = time.time()
    toks = api.greedy_generate(params, prompt, cfg, steps=GEN,
                               t_max=PROMPT + GEN)
    toks = np.asarray(toks)
    dt = time.time() - t0
    outs[layout] = toks
    print(f"{layout:9s}: {BATCH * GEN / dt:7.1f} tok/s   "
          f"sample={toks[0][:8].tolist()}")

assert np.array_equal(outs["oracle"], outs["crossbar"])
assert np.array_equal(outs["oracle"], outs["medusa"])
print("\nall three fabrics generate IDENTICAL tokens — drop-in replacement ✓")
