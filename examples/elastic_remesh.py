"""Elastic re-mesh: restore a checkpoint onto a different mesh.

Saves training state sharded one way, then restores it onto a different
topology (what happens when a pod is lost and the job resumes on fewer
slices).  Checkpoints are host-side and layout-free, so this is exact.

    python examples/elastic_remesh.py       (re-executes itself with 8 devices)
"""

import os
import subprocess
import sys
import tempfile

if os.environ.get("_REMESH_CHILD") != "1":
    env = dict(os.environ, _REMESH_CHILD="1",
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               PYTHONPATH=os.path.join(os.path.dirname(__file__), "..", "src"))
    sys.exit(subprocess.call([sys.executable, __file__], env=env))

import jax                                     # noqa: E402
import jax.numpy as jnp                        # noqa: E402
import numpy as np                             # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.checkpoint import save_checkpoint, restore_checkpoint  # noqa: E402
from repro.launch.mesh import compat_mesh, make_mesh  # noqa: E402

big = make_mesh((4, 2), ("data", "model"))
small = compat_mesh(jax.devices()[:4], (2, 2), ("data", "model"))

state = {"w": jax.device_put(jnp.arange(64.0).reshape(8, 8),
                             NamedSharding(big, P("data", "model"))),
         "step": jnp.int32(7)}
d = tempfile.mkdtemp()
save_checkpoint(d, 7, state)
print(f"saved on 4x2 mesh: {state['w'].sharding}")

template = {"w": jnp.zeros((8, 8)), "step": jnp.int32(0)}
shardings = {"w": NamedSharding(small, P("data", "model")),
             "step": NamedSharding(small, P())}
restored, _ = restore_checkpoint(d, 7, template, shardings)
print(f"restored on 2x2 mesh: {restored['w'].sharding}")
assert np.allclose(np.asarray(restored["w"]), np.arange(64.0).reshape(8, 8))
print("values identical after re-mesh ✓ — elastic recovery path works")
