"""Quickstart: the Medusa interconnect in 60 seconds.

Runs the paper's core algorithm (cycle-accurate + production forms), shows
the complexity model that reproduces the paper's resource claims, and pushes
a batch of lines through the read/write networks.

    PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (medusa_transpose_cycle_accurate, complexity_summary,
                        paper_design_point)
from repro.fabric import BurstScheduler, Fabric

# 1. The transposition unit, cycle by cycle (paper Fig. 4): N=4 ports.
n = 4
banks = jnp.arange(n * n, dtype=jnp.float32).reshape(n, n, 1)
out, trace = medusa_transpose_cycle_accurate(banks, return_trace=True)
print(f"cycle-accurate transpose complete in {len(trace)} cycles "
      f"(constant latency = N = {n})")
assert np.allclose(out, jnp.swapaxes(banks, 0, 1))

# 2. The complexity model at the paper's design point (512-bit DDR3, 32+32
#    16-bit ports) — reproduces §II-B/§III-D/§IV-C.
s = complexity_summary(paper_design_point())
print(f"mux complexity: baseline={s['baseline_mux_bits']} "
      f"medusa={s['medusa_mux_bits']} → {s['mux_reduction']:.1f}x reduction "
      f"(paper: 4.7x LUT / 6.0x FF)")
print(f"BRAM: baseline-if-mapped={s['baseline_bram_if_mapped']} "
      f"medusa={s['medusa_bram']} (paper: 960 vs 64)")

# 3. The production data path: line stream → banked port buffers → back.
fabric = Fabric.make(n_ports=8, impl="medusa")
lines = jax.random.normal(jax.random.PRNGKey(0), (32, 8, 16))
banked = fabric.read(lines)                   # [G, word-addr, port-lane, W]
assert np.allclose(fabric.write(banked), lines)   # write network inverts
print(f"read/write networks round-trip OK: {lines.shape} -> {banked.shape}")

# 4. Drop-in equivalence across fabrics (paper §III-F).
for impl in ("crossbar", "oracle"):
    assert np.allclose(Fabric.make(8, impl).read(lines), banked)
print("medusa == crossbar == oracle (identical transfer semantics)")

# 5. Many logical streams, one network invocation: the burst scheduler.
#    Streams pack along the word axis — each PortSpec records its (offset,
#    words) extent in the shared burst, and the network moves zero padding.
sched = BurstScheduler(fabric)
kv_spec = sched.enqueue_read("kv_read", lines)
wt_spec = sched.enqueue_read("weight_stream",
                             jax.random.normal(jax.random.PRNGKey(1),
                                               (16, 8, 4)))
sched.issue()            # dispatch the burst (input half of the §III-C
moved = sched.commit()   # double buffer); commit adopts the results
assert np.allclose(moved["kv_read"], banked)
print(f"burst scheduler: {sched.stats.streams_served} streams in "
      f"{sched.stats.network_calls} network call(s); extents "
      f"kv_read=({kv_spec.offset},{kv_spec.words}) "
      f"weight_stream=({wt_spec.offset},{wt_spec.words}); "
      f"{sched.stats.words_moved} words moved, "
      f"{sched.stats.words_padded} padded")

# 6. The issue/commit pipeline: while one burst is in flight, the next
#    step's streams stage — transfer overlaps consumer compute.
sched.enqueue_read("kv_read", lines)
sched.issue()
next_step = jax.random.normal(jax.random.PRNGKey(2), (32, 8, 16))
sched.enqueue_read("kv_read_next", next_step)     # stages behind the burst
out = sched.commit()
assert np.allclose(out["kv_read"], banked)
assert np.allclose(sched.flush()["kv_read_next"],
                   Fabric.make(8, "oracle").read(next_step))
print(f"issue/commit pipeline: {sched.stats.flushes} flushes, "
      f"{sched.stats.network_calls} network calls total")
