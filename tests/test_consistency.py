"""Decode-with-cache must match full forward — the strongest cache test."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.models import api, lm
from repro.kernels import ops

KEY = jax.random.PRNGKey(1)


def fp32(cfg):
    cfg = dataclasses.replace(cfg, dtype="float32")
    if cfg.moe is not None:
        # capacity dropping depends on the token population (S-token prefill
        # vs 1-token decode) — give ample capacity so no path drops and the
        # cache semantics can be compared exactly.
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=float(
                cfg.moe.n_experts)))
    return cfg


@pytest.mark.parametrize("arch", ["starcoder2-15b", "gemma3-12b",
                                  "mamba2-780m", "recurrentgemma-2b",
                                  "granite-moe-3b-a800m"])
def test_decode_matches_forward(arch):
    ops.use_kernels(False)
    cfg = fp32(get_smoke(arch))
    S, steps, B = 12, 4, 2
    params = api.init_params(cfg, KEY)
    toks = jax.random.randint(KEY, (B, S + steps), 0, cfg.vocab_size)
    full = lm.forward(params, toks, cfg)
    logits, caches = api.prefill_fn(params, {"tokens": toks[:, :S]}, cfg,
                                    S + steps)
    np.testing.assert_allclose(np.asarray(logits[:, -1]),
                               np.asarray(full[:, S - 1]), atol=2e-4)
    for i in range(steps):
        logits, caches = api.decode_fn(params, toks[:, S + i:S + i + 1],
                                       caches, S + i, cfg)
        np.testing.assert_allclose(np.asarray(logits[:, 0]),
                                   np.asarray(full[:, S + i]), atol=2e-4)


def test_decode_matches_forward_whisper():
    ops.use_kernels(False)
    from repro.models import whisper
    cfg = fp32(get_smoke("whisper-medium"))
    B, S, steps = 2, 10, 3
    params = api.init_params(cfg, KEY)
    frames = jax.random.normal(KEY, (B, cfg.encoder_seq, cfg.d_model))
    toks = jax.random.randint(KEY, (B, S + steps), 0, cfg.vocab_size)
    full = whisper.forward(params, toks, frames, cfg)
    logits, caches = api.prefill_fn(
        params, {"tokens": toks[:, :S], "frames": frames}, cfg, S + steps)
    np.testing.assert_allclose(np.asarray(logits[:, -1]),
                               np.asarray(full[:, S - 1]), atol=2e-4)
    for i in range(steps):
        logits, caches = api.decode_fn(params, toks[:, S + i:S + i + 1],
                                       caches, S + i, cfg)
        np.testing.assert_allclose(np.asarray(logits[:, 0]),
                                   np.asarray(full[:, S + i]), atol=2e-4)


@pytest.mark.parametrize("kv_layout", ["medusa", "crossbar", "oracle", "fused"])
def test_kv_layouts_agree(kv_layout):
    """The paper's claim: the interconnect fabric is a drop-in replacement —
    identical data-transfer semantics across all three implementations."""
    ops.use_kernels(kv_layout == "medusa")
    try:
        cfg = dataclasses.replace(fp32(get_smoke("starcoder2-15b")),
                                  kv_layout=kv_layout)
        params = api.init_params(cfg, KEY)
        S, B = 8, 2
        toks = jax.random.randint(KEY, (B, S + 1), 0, cfg.vocab_size)
        full = lm.forward(params, toks, cfg)
        _, caches = api.prefill_fn(params, {"tokens": toks[:, :S]}, cfg, S + 1)
        logits, _ = api.decode_fn(params, toks[:, S:S + 1], caches, S, cfg)
        np.testing.assert_allclose(np.asarray(logits[:, 0]),
                                   np.asarray(full[:, S]), atol=2e-4)
    finally:
        ops.use_kernels(True)


def test_mamba_chunked_vs_sequential():
    ops.use_kernels(False)
    from repro.models.mamba2 import (mamba_params, mamba_apply,
                                     mamba_sequential_ref)
    cfg = fp32(get_smoke("mamba2-780m"))
    p = mamba_params(KEY, cfg, jnp.float32)
    x = jax.random.normal(KEY, (2, 24, cfg.d_model))
    y1, _ = mamba_apply(p, x, cfg)
    y2 = mamba_sequential_ref(p, x, cfg)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-4)


def test_rglru_scan_vs_sequential():
    ops.use_kernels(False)
    from repro.models.rglru import (rglru_params, rglru_apply,
                                    rglru_sequential_ref)
    cfg = fp32(get_smoke("recurrentgemma-2b"))
    p = rglru_params(KEY, cfg, jnp.float32)
    x = jax.random.normal(KEY, (2, 16, cfg.d_model))
    y1, _ = rglru_apply(p, x, cfg)
    y2 = rglru_sequential_ref(p, x, cfg)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-4)


def test_chunked_attention_matches_full():
    ops.use_kernels(False)
    from repro.models.common import attention
    B, S, H, HKV, D = 2, 64, 4, 2, 8
    q = jax.random.normal(KEY, (B, S, H, D))
    k = jax.random.normal(jax.random.fold_in(KEY, 1), (B, S, HKV, D))
    v = jax.random.normal(jax.random.fold_in(KEY, 2), (B, S, HKV, D))
    pos = jnp.arange(S)
    full = attention(q, k, v, pos, pos, causal=True)
    chunked = attention(q, k, v, pos, pos, causal=True, kv_chunk=16)
    np.testing.assert_allclose(np.asarray(full), np.asarray(chunked),
                               atol=2e-5)
    # sliding window agreement too
    fw = attention(q, k, v, pos, pos, causal=True, window=8)
    cw = attention(q, k, v, pos, pos, causal=True, window=8, kv_chunk=16)
    np.testing.assert_allclose(np.asarray(fw), np.asarray(cw), atol=2e-5)
