"""Machine-word lane folding + fused single-kernel bursts: parity and
accounting.

The PR 3 acceptance bar: every ``word_fold`` ∈ {auto, 1, 2, 4} × burst path
{fused kernel, unrolled} × layout {packed, pad} combination is a bit-exact
round trip on arbitrary stream mixes (dtypes × widths × group counts, odd
word counts included), the fold resolution degrades gracefully instead of
erroring, and the new ``SchedulerStats`` counters (``words_folded``,
``kernel_bursts``) reflect the post-fold traffic.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import FabricConfig
from repro.core.transpose import read_network_oracle
from repro.fabric import BurstScheduler, Fabric, SchedulerStats
from repro.fabric import scheduler as sched_mod
from repro.kernels import ops
from repro.kernels.medusa_transpose import burst_network_tiles

from tests.hypothesis_compat import given, settings, st

KEY = jax.random.PRNGKey(11)
IMPLS = ("medusa", "crossbar", "oracle")


def _stream(i: int, n: int, groups: int, width, dtype):
    k = jax.random.fold_in(KEY, i)
    shape = (groups * n, n) + (() if width is None else (width,))
    if jnp.issubdtype(jnp.dtype(dtype), jnp.integer):
        return jax.random.randint(k, shape, 0, 97).astype(dtype)
    return jax.random.normal(k, shape).astype(dtype)


def _roundtrip(impl, pack, fold, streams, n):
    """Read-burst every stream, then write-burst the results back; assert
    both directions bit-identical to the per-stream oracle."""
    sched = BurstScheduler(Fabric.make(n, impl, pack=pack), word_fold=fold)
    for name, x in streams.items():
        sched.enqueue_read(name, x)
    out = sched.flush()
    for name, x in streams.items():
        assert out[name].dtype == x.dtype
        np.testing.assert_array_equal(
            np.asarray(out[name], np.float32),
            np.asarray(read_network_oracle(x, n), np.float32),
            err_msg=f"read {impl}/{pack}/fold={fold}/{name}")
    for name in streams:
        sched.enqueue_write(name, out[name])
    back = sched.flush()
    for name, x in streams.items():
        np.testing.assert_array_equal(
            np.asarray(back[name], np.float32), np.asarray(x, np.float32),
            err_msg=f"write {impl}/{pack}/fold={fold}/{name}")
    return sched.stats


# ---------------------------------------------------------------------------
# deterministic parity matrix (fast lane)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("fold", ("auto", 1, 2, 4))
@pytest.mark.parametrize("pack", ("packed", "pad"))
@pytest.mark.parametrize("kernels", (False, True))
def test_fold_kernel_pack_matrix_bit_identical(fold, pack, kernels):
    """The acceptance matrix on a fixed mixed mix: even widths (in-group
    fold), an odd width with even groups (cross-group fold), a wordless
    stream, and an odd-by-odd stream that blocks folding for its dtype
    group — every combination is a bit-exact round trip."""
    n = 4
    streams = {
        "kv": _stream(0, n, 8, 16, jnp.bfloat16),
        "wt_odd_width": _stream(1, n, 2, 5, jnp.bfloat16),
        "moe": _stream(2, n, 4, None, jnp.float32),
        "stage_i32": _stream(3, n, 2, 3, jnp.int32),
        "odd_odd": _stream(4, n, 3, 7, jnp.float32),
    }
    prev = ops.kernels_enabled()
    ops.use_kernels(kernels)
    try:
        for impl in IMPLS:
            stats = _roundtrip(impl, pack, fold, streams, n)
            kernelized = (impl == "medusa" and kernels and pack == "packed")
            assert (stats.kernel_bursts > 0) == kernelized
    finally:
        ops.use_kernels(prev)


def test_fold_resolution_degrades_gracefully():
    """auto folds the widest the dtype/geometry allow: bf16 pairs → u32
    without x64 (quads need the u64 lane); a stream odd in both width and
    groups pins its whole dtype group at fold 1; the pad layout folds on
    its padded width (including the padding, which rides the wider lanes
    too — that's what isolates packing from lane width in the A/B)."""
    n = 4
    even = {"a": _stream(0, n, 2, 8, jnp.bfloat16),
            "b": _stream(1, n, 4, 3, jnp.bfloat16)}   # odd width, even groups
    sched = BurstScheduler(Fabric.make(n, "oracle"), word_fold="auto")
    for name, x in even.items():
        sched.enqueue_read(name, x)
    sched.flush()
    moved = sum(2 * n * n * 8 + 4 * n * n * 3 for _ in (1,))
    assert sched.stats.words_moved == moved
    assert sched.stats.words_folded == moved // 2     # fold 2, not 4 (no x64)

    blocker = {"a": _stream(0, n, 2, 8, jnp.bfloat16),
               "odd": _stream(2, n, 3, 5, jnp.bfloat16)}  # 3 groups x 5 words
    sched = BurstScheduler(Fabric.make(n, "oracle"), word_fold="auto")
    for name, x in blocker.items():
        sched.enqueue_read(name, x)
    sched.flush()
    assert sched.stats.words_folded == 0              # group degraded to 1

    sched = BurstScheduler(Fabric.make(n, "oracle", pack="pad"),
                           word_fold="auto")
    for name, x in even.items():
        sched.enqueue_read(name, x)
    sched.flush()
    # pad folds the padded lane view (w_max=8 divides 2): half the
    # moved+padded elements ride inside u32 machine words
    lane_view = sched.stats.words_moved + sched.stats.words_padded
    assert sched.stats.words_folded == lane_view // 2

    sched = BurstScheduler(Fabric.make(n, "oracle", pack="pad"), word_fold=1)
    for name, x in even.items():
        sched.enqueue_read(name, x)
    sched.flush()
    assert sched.stats.words_folded == 0              # fold=1: raw baseline


def test_word_fold_validates():
    with pytest.raises(ValueError):
        FabricConfig(word_fold=3).validate()
    with pytest.raises(ValueError):
        BurstScheduler(Fabric.make(4, "oracle"), word_fold="wide")
    assert FabricConfig(word_fold=4).validate().word_fold == 4


def test_scheduler_stats_kernel_bursts_counter():
    """kernel_bursts counts exactly the network calls that lowered through
    the fused Pallas burst (medusa + kernels enabled); the crossbar and the
    kernels-off path never kernelize."""
    n = 4
    prev = ops.kernels_enabled()
    try:
        ops.use_kernels(True)
        stats = _roundtrip("medusa", "packed", 1,
                           {"a": _stream(0, n, 2, 4, jnp.float32)}, n)
        assert stats.kernel_bursts == 2               # 1 read + 1 write
        assert stats.network_calls == 2
        stats = _roundtrip("crossbar", "packed", 1,
                           {"a": _stream(0, n, 2, 4, jnp.float32)}, n)
        assert stats.kernel_bursts == 0
        ops.use_kernels(False)
        stats = _roundtrip("medusa", "packed", 1,
                           {"a": _stream(0, n, 2, 4, jnp.float32)}, n)
        assert stats.kernel_bursts == 0
    finally:
        ops.use_kernels(prev)


def test_word_view_u64_under_x64():
    """The 8-byte ``_WORD_VIEW`` entry: float64 payloads ride the u64
    integer-view fast path when x64 is enabled (they used to silently skip
    it), and bf16 groups fold x4 into u64 lanes."""
    from jax.experimental import enable_x64
    with enable_x64():
        n = 4
        f64 = jax.random.normal(KEY, (2 * n, n, 6), jnp.float64)
        assert sched_mod._int_view(f64).dtype == jnp.uint64
        bf = jax.random.normal(KEY, (4 * n, n, 8)).astype(jnp.bfloat16)
        sched = BurstScheduler(Fabric.make(n, "medusa"), word_fold="auto")
        sched.enqueue_read("f64", f64)
        sched.enqueue_read("bf", bf)
        out = sched.flush()
        np.testing.assert_array_equal(np.asarray(out["f64"]),
                                      np.asarray(read_network_oracle(f64, n)))
        np.testing.assert_array_equal(
            np.asarray(out["bf"], np.float32),
            np.asarray(read_network_oracle(bf, n), np.float32))
        # bf16 stream folds x4 (2B * 4 = u64); f64 cannot widen past 8B
        bf_elems = 4 * n * n * 8
        assert sched.stats.words_folded == bf_elems - bf_elems // 4


def test_word_view_f64_skips_without_x64():
    """Without x64 an 8-byte payload has no machine-word view — the helper
    returns None instead of a dtype jax would silently truncate."""
    assert sched_mod.machine_word_dtype(8) is None
    assert sched_mod.machine_word_dtype(4) == jnp.uint32


# ---------------------------------------------------------------------------
# fused burst kernel
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,w", [(2, 6), (4, 37), (8, 129), (8, 4097)])
def test_burst_network_tiles_matches_oracle(n, w):
    """The single-kernel burst (word-tiled grid, pad-and-slice for widths
    past the tile cap) is the read network on one [N, N, W] tile — and its
    own inverse (write direction)."""
    x = jax.random.randint(jax.random.fold_in(KEY, w), (n, n, w), 0, 2**16,
                           jnp.uint32).astype(jnp.uint16)
    out = burst_network_tiles(x, n)
    np.testing.assert_array_equal(np.asarray(out),
                                  np.asarray(read_network_oracle(x, n)[0]))
    back = burst_network_tiles(out, n)
    np.testing.assert_array_equal(np.asarray(back), np.asarray(x))


def test_fabric_burst_contract_validates():
    fab = Fabric.make(4, "medusa")
    with pytest.raises(ValueError):
        fab.read_burst(jnp.zeros((4, 3, 8)))
    with pytest.raises(ValueError):
        fab.write_burst(jnp.zeros((2, 4, 4, 8)))      # banked rank-4 is not a tile
    out = fab.read_burst(jnp.arange(4 * 4 * 2, dtype=jnp.float32
                                    ).reshape(4, 4, 2))
    assert out.shape == (4, 4, 2)


def test_complex_payloads_skip_fold_and_kernel():
    """Complex streams round-trip on the unrolled path: bitcast rejects
    complex (no integer view, no fold) and Pallas interpret on this jax
    cannot stage complex buffers (no fused kernel) — both degrade silently
    instead of crashing."""
    n = 4
    k1, k2 = jax.random.split(KEY)
    c64 = (jax.random.normal(k1, (2 * n, n, 3))
           + 1j * jax.random.normal(k2, (2 * n, n, 3))).astype(jnp.complex64)
    prev = ops.kernels_enabled()
    ops.use_kernels(True)
    try:
        sched = BurstScheduler(Fabric.make(n, "medusa"), word_fold="auto")
        sched.enqueue_read("c", c64)
        out = sched.flush()
        np.testing.assert_array_equal(np.asarray(out["c"]),
                                      np.asarray(read_network_oracle(c64, n)))
        assert sched.stats.words_folded == 0
        assert sched.stats.kernel_bursts == 0
    finally:
        ops.use_kernels(prev)


def test_non_pow2_ports_fall_back_to_unrolled():
    """A 3-port medusa fabric cannot run the log2-stage kernel; the burst
    contract silently takes the unrolled path (and the scheduler's counter
    agrees)."""
    fab = Fabric.make(3, "oracle")
    assert not fab.burst_kernelized
    stats = _roundtrip("oracle", "packed", "auto",
                       {"a": _stream(0, 3, 2, 4, jnp.float32)}, 3)
    assert stats.kernel_bursts == 0


# ---------------------------------------------------------------------------
# hypothesis sweep: random stream mixes (slow lane)
# ---------------------------------------------------------------------------

_DTYPES = (jnp.bfloat16, jnp.float32, jnp.int32, jnp.uint8)


@pytest.mark.slow
@settings(max_examples=25, deadline=None)
@given(st.data())
def test_fold_kernel_parity_random_mixes(data):
    """Random stream mixes — dtypes × widths × group counts, odd word
    counts included — are bit-identical round trips under every
    word_fold × {kernel, unrolled} × {packed, pad} combination."""
    n = data.draw(st.sampled_from((2, 4, 8)), label="n_ports")
    n_streams = data.draw(st.integers(1, 4), label="n_streams")
    streams = {}
    for i in range(n_streams):
        dtype = data.draw(st.sampled_from(_DTYPES), label=f"dtype{i}")
        groups = data.draw(st.integers(1, 5), label=f"groups{i}")
        width = data.draw(st.sampled_from((None, 1, 2, 3, 4, 7, 8)),
                          label=f"width{i}")
        streams[f"s{i}"] = _stream(i, n, groups, width, dtype)
    fold = data.draw(st.sampled_from(("auto", 1, 2, 4)), label="fold")
    pack = data.draw(st.sampled_from(("packed", "pad")), label="pack")
    kernels = data.draw(st.booleans(), label="kernels")
    impl = data.draw(st.sampled_from(IMPLS), label="impl")
    prev = ops.kernels_enabled()
    ops.use_kernels(kernels)
    try:
        _roundtrip(impl, pack, fold, streams, n)
    finally:
        ops.use_kernels(prev)


# ---------------------------------------------------------------------------
# scheduled serving decode stays bit-identical under fold x kernel
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("fold", (1, 2, "auto"))
@pytest.mark.parametrize("kernels", (False, True))
def test_scheduled_decode_bit_identical_under_fold_kernel(fold, kernels):
    """The production consumer: a burst-scheduled decode step (KV banking +
    serve_fsdp weight stream) returns bit-identical logits and caches to
    the unscheduled per-layer reference under every fold/kernel
    combination."""
    from repro.configs import get_smoke
    from repro.models import api

    prev = ops.kernels_enabled()
    ops.use_kernels(kernels)
    try:
        cfg = dataclasses.replace(get_smoke("starcoder2-15b"),
                                  dtype="float32", serve_fsdp=True)
        cfg = dataclasses.replace(
            cfg, fabric=dataclasses.replace(cfg.resolved_fabric,
                                            word_fold=fold))
        params = api.init_params(cfg, KEY)
        toks = jax.random.randint(KEY, (2, 9), 0, cfg.vocab_size)
        _, caches = api.prefill_fn(params, {"tokens": toks[:, :8]}, cfg, 12)
        ref_logits, ref_caches = api.decode_fn(params, toks[:, 8:9], caches,
                                               jnp.int32(8), cfg)
        stats = SchedulerStats()
        sched = BurstScheduler(Fabric(cfg.resolved_fabric), stats=stats)
        logits, new_caches = api.decode_fn(params, toks[:, 8:9], caches,
                                           jnp.int32(8), cfg, sched=sched)
        assert stats.flushes == 2
        if kernels:
            assert stats.kernel_bursts == stats.network_calls
        # f32 folds need u64 (x64 off) → fold degrades to 1 silently
        assert stats.words_folded == 0
        np.testing.assert_array_equal(np.asarray(logits),
                                      np.asarray(ref_logits))
        jax.tree.map(lambda a, b: np.testing.assert_array_equal(
            np.asarray(a), np.asarray(b)), ref_caches, new_caches)
    finally:
        ops.use_kernels(prev)
