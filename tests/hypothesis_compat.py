"""Optional-dependency shim for hypothesis.

Property tests decorate with ``@given``/``@settings`` and draw from ``st``.
When hypothesis is installed these are the real objects; when it is not
(minimal CPU containers), the decorators replace each property test with a
skipped placeholder so the *rest* of the module still collects and runs —
a module-level ``pytest.importorskip`` would throw away the plain tests too.
"""

import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:          # pragma: no cover - exercised in minimal images
    HAVE_HYPOTHESIS = False

    def _skip_decorator(*_args, **_kwargs):
        def deco(fn):
            @pytest.mark.skip(reason="hypothesis not installed")
            def _skipped():
                pass
            _skipped.__name__ = getattr(fn, "__name__", "property_test")
            return _skipped
        return deco

    given = settings = _skip_decorator

    class _Strategies:
        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _Strategies()

__all__ = ["given", "settings", "st", "HAVE_HYPOTHESIS"]
