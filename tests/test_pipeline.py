"""Pipeline parallelism numerics: pipelined == sequential, fwd and bwd."""
import os
import subprocess
import sys

ROOT = os.path.join(os.path.dirname(__file__), "..")


def test_pipeline_matches_sequential():
    code = """
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.parallel.pipeline import pipeline_forward, pipeline_loss, bubble_fraction

P_STAGES, M, MB, D = 4, 6, 2, 8
from repro.launch.mesh import compat_shard_map, make_mesh
mesh = make_mesh((P_STAGES,), ("pipe",))
ws = jax.random.normal(jax.random.PRNGKey(0), (P_STAGES, D, D)) * 0.3
xs = jax.random.normal(jax.random.PRNGKey(1), (M, MB, D))
tg = jax.random.normal(jax.random.PRNGKey(2), (M, MB, D))

stage = lambda w, x: jnp.tanh(x @ w[0])

def run(ws_all, xs):
    return pipeline_forward(stage, ws_all, xs, "pipe")

piped = compat_shard_map(run, mesh=mesh, in_specs=(P("pipe"), P()),
                      out_specs=P(), check_vma=False)(ws, xs)

seq = xs
for s in range(P_STAGES):
    seq = jnp.tanh(seq @ ws[s])
np.testing.assert_allclose(np.asarray(piped), np.asarray(seq), atol=1e-5)

# backward: grads through the pipeline match sequential grads
def loss_piped(ws_all):
    f = compat_shard_map(
        lambda w, x, t: pipeline_loss(stage, lambda o, t: jnp.mean((o - t) ** 2),
                                      w, x, t, "pipe")[None],
        mesh=mesh, in_specs=(P("pipe"), P(), P()), out_specs=P(None),
        check_vma=False)
    return f(ws_all, xs, tg).sum()

def loss_seq(ws_all):
    h = xs
    for s in range(P_STAGES):
        h = jnp.tanh(h @ ws_all[s])
    return jax.vmap(lambda o, t: jnp.mean((o - t) ** 2))(h, tg).mean()

g1 = jax.grad(loss_piped)(ws)
g2 = jax.grad(loss_seq)(ws)
np.testing.assert_allclose(np.asarray(g1), np.asarray(g2), atol=1e-5)
assert abs(bubble_fraction(6, 4) - 3 / 9) < 1e-9
print("OK")
"""
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=4",
               PYTHONPATH=os.path.join(ROOT, "src"))
    r = subprocess.run([sys.executable, "-c", code], env=env,
                       capture_output=True, text=True, timeout=300)
    assert "OK" in r.stdout, (r.stdout[-1500:], r.stderr[-1500:])
