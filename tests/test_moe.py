import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, MoEConfig
from repro.models.moe import moe_params, moe_apply, aux_load_balance_loss

KEY = jax.random.PRNGKey(0)


def _cfg(**kw):
    base = dict(name="t", family="moe", n_layers=1, d_model=16, n_heads=2,
                n_kv_heads=2, d_ff=0, vocab_size=64,
                moe=MoEConfig(n_experts=4, top_k=2, expert_d_ff=32,
                              capacity_factor=4.0))
    base.update(kw)
    return ModelConfig(**base)


def _dense_oracle(p, x, cfg):
    """Dense-einsum oracle: route every token through every expert, weight by
    normalised top-k router probs (high capacity → identical semantics)."""
    m = cfg.moe
    t = x.shape[0] * x.shape[1]
    xt = x.reshape(t, -1)
    logits = xt.astype(jnp.float32) @ p["router"]
    probs = jax.nn.softmax(logits, -1)
    top_p, top_e = jax.lax.top_k(probs, m.top_k)
    top_p = top_p / top_p.sum(-1, keepdims=True)
    w = jnp.zeros((t, m.n_experts)).at[
        jnp.arange(t)[:, None], top_e].set(top_p)
    h = jax.nn.silu(jnp.einsum("td,edf->tef", xt, p["w_gate"])) * \
        jnp.einsum("td,edf->tef", xt, p["w_up"])
    y = jnp.einsum("tef,efd->ted", h, p["w_out"])
    return jnp.einsum("te,ted->td", w.astype(x.dtype), y).reshape(x.shape)


def test_moe_matches_dense_oracle_when_capacity_ample():
    cfg = _cfg()
    p = moe_params(KEY, cfg, jnp.float32)
    x = jax.random.normal(KEY, (2, 8, cfg.d_model))
    got = moe_apply(p, x, cfg)
    want = _dense_oracle(p, x, cfg)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-4)


def test_moe_capacity_drops_overflow():
    # capacity_factor tiny → most tokens dropped → output ~smaller norm
    cfg_lo = _cfg(moe=MoEConfig(n_experts=4, top_k=2, expert_d_ff=32,
                                capacity_factor=0.1))
    p = moe_params(KEY, cfg_lo, jnp.float32)
    x = jax.random.normal(KEY, (2, 16, cfg_lo.d_model))
    got = moe_apply(p, x, cfg_lo)
    assert np.isfinite(np.asarray(got)).all()
    full = moe_apply(p, x, _cfg())
    assert np.linalg.norm(np.asarray(got)) < np.linalg.norm(np.asarray(full))


def test_aux_loss_positive_and_finite():
    cfg = _cfg()
    p = moe_params(KEY, cfg, jnp.float32)
    x = jax.random.normal(KEY, (2, 8, cfg.d_model))
    aux = aux_load_balance_loss(p, x, cfg)
    assert float(aux) >= 1.0 - 1e-3  # >= 1 by Cauchy-Schwarz at balance
