"""Multi-device behaviours (ring collectives, shard_map DP, dry-run cell) —
each in a subprocess with its own XLA_FLAGS (never set globally)."""
import json
import os
import subprocess
import sys

import pytest

ROOT = os.path.join(os.path.dirname(__file__), "..")


def _run(code, devices=8, timeout=420, env_extra=None):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    env.update(env_extra or {})
    return subprocess.run([sys.executable, "-c", code], env=env,
                          capture_output=True, text=True, timeout=timeout)


def test_ring_all_to_all_equals_xla():
    r = _run("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.parallel.collectives import ring_all_to_all, xla_all_to_all
from repro.launch.mesh import compat_shard_map, make_mesh
mesh = make_mesh((8,), ("x",))
x = jax.random.normal(jax.random.PRNGKey(0), (64, 4))
ring = compat_shard_map(lambda a: ring_all_to_all(a, "x"), mesh=mesh,
                     in_specs=P("x"), out_specs=P("x"))
xla = compat_shard_map(lambda a: xla_all_to_all(a, "x"), mesh=mesh,
                    in_specs=P("x"), out_specs=P("x"))
np.testing.assert_allclose(np.asarray(ring(x)), np.asarray(xla(x)))
print("OK")
""")
    assert "OK" in r.stdout, r.stderr[-2000:]


def test_shard_map_dp_with_compression():
    r = _run("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.parallel.collectives import dp_grad_mean
from repro.launch.mesh import compat_shard_map, make_mesh
mesh = make_mesh((8,), ("dp",))
w = jnp.ones((16,))
def step(w, xb):
    # params enter as an explicit replicated input (realistic DP pattern)
    g = jax.grad(lambda w: jnp.sum((xb @ w.reshape(16, 1)) ** 2))(w)
    return dp_grad_mean({"w": g}, "dp", compression="int8")["w"]
x = jax.random.normal(jax.random.PRNGKey(0), (32, 16))
out = compat_shard_map(step, mesh=mesh, in_specs=(P(), P("dp")),
                    out_specs=P(), check_vma=False)(w, x)
ref = jax.grad(lambda w: jnp.mean(jax.vmap(
    lambda xb: jnp.sum((xb @ w.reshape(16, 1)) ** 2))(x.reshape(8, 4, 16))))(w)
rel = np.abs(np.asarray(out - ref)).max() / np.abs(np.asarray(ref)).max()
assert rel < 0.05, rel
print("OK")
""")
    assert "OK" in r.stdout, r.stderr[-2000:]


@pytest.mark.slow
def test_dryrun_cell_subprocess(tmp_path):
    """One full dry-run cell on the 512-device production mesh."""
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch",
         "stablelm-1.6b", "--shape", "decode_32k", "--mesh", "single",
         "--force"],
        env={**os.environ, "PYTHONPATH": os.path.join(ROOT, "src"),
             "REPRO_RESULTS_DIR": str(tmp_path)},
        capture_output=True, text=True, timeout=560, cwd=ROOT)
    assert "ok:" in r.stdout, (r.stdout[-2000:], r.stderr[-2000:])
    out = json.load(open(os.path.join(
        str(tmp_path), "stablelm-1.6b__decode_32k__single.json")))
    assert out["status"] == "ok"
    assert out["roofline"]["dominant"] in ("compute", "memory", "collective")
