"""Graceful degradation under oversubscription: page-level swap, priority
preemption, and fault-injected serving.

The acceptance bar:

* an oversubscribed churn run (queued demand ≥ 2× pool pages, mixed
  priority classes) completes with every request's generated tokens
  **bit-identical** to an unconstrained-pool reference run — for both the
  swap arm (``swap/*`` fabric streams, ``preemptions > 0``, swap words in
  ``SchedulerStats``) and the recompute arm (pages dropped, the sequence so
  far re-prefilled);
* a high-priority request lands within a couple of steps of arrival even
  when lower-priority work holds every page (no priority inversion — not
  even through the swap space);
* injected faults — mid-step failure (snapshot/replay), corrupted swap
  bursts (parity-checked, retried once), transient pool exhaustion — all
  recover with zero output divergence;
* requests that could never run are rejected at ``submit()``, and
  ``run_to_completion`` raises instead of silently stranding work.
"""

import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.fabric import PagePool
from repro.kernels import ops
from repro.models import api
from repro.runtime.fault_tolerance import FaultInjector
from repro.serving import Request, ServingEngine

from tests.hypothesis_compat import given, settings, st

KEY = jax.random.PRNGKey(7)


def _cfg():
    return dataclasses.replace(get_smoke("starcoder2-15b"), dtype="float32")


_PARAMS = {}


def _params(cfg):
    if cfg.name not in _PARAMS:
        _PARAMS[cfg.name] = api.init_params(cfg, KEY)
    return _PARAMS[cfg.name]


def _prompt(rid: int, length: int, vocab: int) -> np.ndarray:
    return np.asarray(jax.random.randint(jax.random.fold_in(KEY, 1000 + rid),
                                         (length,), 0, vocab), np.int32)


# (arrival_step, prompt_len, max_new_tokens, priority): two long-running
# low-priority requests saturate a 7-page pool (reach 16 tokens = 4 pages
# each), then higher classes arrive — queued demand is ≥ 2× the pool.
SPEC = [(0, 7, 8, 0), (0, 8, 8, 0), (2, 9, 6, 2), (3, 7, 6, 1), (4, 6, 6, 2)]
POOL = 7


def _run(cfg, spec, *, pool_pages, preempt, max_slots=2, t_max=24,
         page_size=4, max_steps=300, inj=None, **eng_kw):
    """Drive scripted arrivals to completion; returns (requests, engine)."""
    eng = ServingEngine(cfg, _params(cfg), max_slots=max_slots, t_max=t_max,
                        page_size=page_size, pool_pages=pool_pages,
                        preempt=preempt, check_pool=True, fault_injector=inj,
                        **eng_kw)
    reqs = [Request(i, _prompt(i, pl, cfg.vocab_size), max_new_tokens=mn,
                    priority=p)
            for i, (_, pl, mn, p) in enumerate(spec)]
    pend = sorted(range(len(spec)), key=lambda i: spec[i][0])
    for step in range(max_steps):
        while pend and spec[pend[0]][0] <= step:
            eng.submit(reqs[pend.pop(0)])
        if (eng.step() == 0 and not eng.queue and not eng._swapped
                and not pend):
            break
    assert all(r.done for r in reqs), "driver ran out of steps"
    return reqs, eng


def _reference(cfg, spec, **kw):
    """Unconstrained run: default-size pool, a slot per request, preemption
    off — the bit-parity oracle every degraded run must match."""
    reqs, _ = _run(cfg, spec, pool_pages=0, preempt="off",
                   max_slots=len(spec), **kw)
    return [r.generated for r in reqs]


def _assert_parity(reqs, ref):
    for r, want in zip(reqs, ref):
        assert r.generated == want, (r.rid, r.generated, want)


# ---------------------------------------------------------------------------
# PagePool swap space (unit level)
# ---------------------------------------------------------------------------

def test_pool_swap_counters_and_conservation():
    pool = PagePool(page_size=4, n_pages=6, pages_per_slot=4, n_slots=3)
    pool.ensure(0, 3)
    pool.ensure(1, 2)
    freed = pool.swap_out(0)
    assert freed == 3 and pool.pages_swapped_out == 3
    assert pool.mapped(0) == 0 and pool.free_pages == 4
    pool.check()                       # release-based: counters balance
    new = pool.swap_in(0, 3)
    assert len(new) == 3 and pool.pages_swapped_in == 3
    assert pool.mapped(0) == 3
    pool.check()
    # swap-in competes with ensure like any allocation: exhaustion raises
    pool.ensure(2, 1)
    with pytest.raises(RuntimeError, match="exhausted"):
        pool.swap_in(1, 3)


# ---------------------------------------------------------------------------
# submit() rejection + run_to_completion stall (the livelock bugfix)
# ---------------------------------------------------------------------------

def test_submit_rejects_reach_beyond_pool():
    ops.use_kernels(False)
    cfg = _cfg()
    eng = ServingEngine(cfg, _params(cfg), max_slots=2, t_max=24,
                        page_size=4, pool_pages=2)
    ok = Request(0, _prompt(0, 5, cfg.vocab_size), max_new_tokens=2)
    eng.submit(ok)                     # reach 7 → 2 pages: fits exactly
    with pytest.raises(ValueError, match="block the queue forever"):
        eng.submit(Request(1, _prompt(1, 9, cfg.vocab_size),
                           max_new_tokens=8))     # reach 17 → 5 pages


def test_submit_rejects_prompt_beyond_t_max():
    ops.use_kernels(False)
    cfg = _cfg()
    eng = ServingEngine(cfg, _params(cfg), max_slots=2, t_max=16, page_size=4)
    with pytest.raises(ValueError, match="cannot decode"):
        eng.submit(Request(0, _prompt(0, 16, cfg.vocab_size),
                           max_new_tokens=1))


def test_run_to_completion_raises_on_exhausted_steps():
    ops.use_kernels(False)
    cfg = _cfg()
    eng = ServingEngine(cfg, _params(cfg), max_slots=1, t_max=24, page_size=4)
    eng.submit(Request(0, _prompt(0, 5, cfg.vocab_size), max_new_tokens=6))
    with pytest.raises(RuntimeError, match="steps exhausted"):
        eng.run_to_completion(max_steps=2)
    eng.run_to_completion(max_steps=32)           # and then it can finish


# ---------------------------------------------------------------------------
# oversubscribed churn: bit-parity under preemption
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mode", ("swap", "recompute"))
def test_oversubscribed_churn_bit_identical(mode):
    """Demand ≥ 2× pool pages, mixed priorities: every request's tokens
    match the unconstrained reference bit-for-bit, preemption actually
    fired, and — swap arm — the ``swap/*`` traffic shows in the stats."""
    ops.use_kernels(False)
    cfg = _cfg()
    ref = _reference(cfg, SPEC)
    reqs, eng = _run(cfg, SPEC, pool_pages=POOL, preempt=mode)
    _assert_parity(reqs, ref)
    st = eng.fabric_stats
    assert st.preemptions > 0
    if mode == "swap":
        assert st.swap_bursts > 0
        assert st.swap_out_words > 0 and st.swap_in_words > 0
        assert eng.kv.pool.pages_swapped_out > 0
        assert eng.kv.pool.pages_swapped_in == eng.kv.pool.pages_swapped_out
    else:
        assert st.swap_out_words == 0 and st.swap_in_words == 0
    # everything retired: full reclamation, empty swap space
    assert eng.kv.pool.pages_in_use == 0
    assert eng._swap_pages_used == 0 and not eng._swapped


def test_preempt_off_blocks_head_of_line():
    """The seed gate survives as ``preempt="off"``: same parity bar, no
    preemption — admission just waits for reclamation."""
    ops.use_kernels(False)
    cfg = _cfg()
    ref = _reference(cfg, SPEC)
    reqs, eng = _run(cfg, SPEC, pool_pages=POOL, preempt="off")
    _assert_parity(reqs, ref)
    assert eng.fabric_stats.preemptions == 0


def test_swap_space_cap_falls_back_to_recompute():
    """A full swap space (``swap_space_pages``) downgrades eviction to the
    recompute arm instead of failing — same parity bar."""
    ops.use_kernels(False)
    cfg = _cfg()
    ref = _reference(cfg, SPEC)
    reqs, eng = _run(cfg, SPEC, pool_pages=POOL, preempt="swap",
                     swap_space_pages=3)
    _assert_parity(reqs, ref)
    assert eng.fabric_stats.preemptions > 0
    assert eng.kv.pool.pages_swapped_out <= 3


def test_priority_inversion_regression():
    """A high-priority arrival lands within K steps even though
    lower-priority work holds every page — the victim policy evicts
    (lowest class, most pages, LRU) instead of queueing behind it."""
    ops.use_kernels(False)
    cfg = _cfg()
    # pool of 8 = exactly two low-priority reaches (4 pages each): both
    # slots fill, zero headroom — the high arrival MUST evict to land
    eng = ServingEngine(cfg, _params(cfg), max_slots=2, t_max=24,
                        page_size=4, pool_pages=8, preempt="swap",
                        check_pool=True)
    for i in range(3):                 # low-priority: fills slots AND queue
        eng.submit(Request(i, _prompt(i, 8, cfg.vocab_size),
                           max_new_tokens=8, priority=0))
    for _ in range(3):
        eng.step()
    hi = Request(99, _prompt(99, 6, cfg.vocab_size), max_new_tokens=4,
                 priority=5)
    eng.submit(hi)
    K = 2
    for _ in range(K):
        eng.step()
        if hi in eng.active:
            break
    assert hi in eng.active, "high-priority request not admitted within K"
    assert eng.fabric_stats.preemptions > 0
    eng.run_to_completion(max_steps=200)
    assert hi.done


# ---------------------------------------------------------------------------
# fault injection: recovery without divergence
# ---------------------------------------------------------------------------

def test_midstep_fault_recovers_bit_identical():
    """An injected mid-step failure rolls back to the pre-step snapshot and
    replays — admission, preemption, pool state and request tails all
    restore, and the outputs match the fault-free reference."""
    ops.use_kernels(False)
    cfg = _cfg()
    ref = _reference(cfg, SPEC)
    inj = FaultInjector(fail_at=(3,))
    reqs, eng = _run(cfg, SPEC, pool_pages=POOL, preempt="swap", inj=inj)
    _assert_parity(reqs, ref)
    assert eng.fabric_stats.faults_recovered == 1
    assert inj.fired == {3}


def test_corrupted_swap_burst_retried_to_parity():
    """In-flight corruption of a swap burst trips the end-to-end parity
    word; the transfer retries once on a clean channel and the run stays
    bit-identical."""
    ops.use_kernels(False)
    cfg = _cfg()
    ref = _reference(cfg, SPEC)
    inj = FaultInjector(corrupt_swap=(0,))
    reqs, eng = _run(cfg, SPEC, pool_pages=POOL, preempt="swap", inj=inj)
    _assert_parity(reqs, ref)
    assert inj.corrupted == 1
    assert eng.fabric_stats.bursts_retried >= 1


def test_injected_pool_exhaustion_backs_off():
    """Transient allocation failure: admission sees zero headroom for the
    scheduled steps, backs off, and the workload still completes to
    parity."""
    ops.use_kernels(False)
    cfg = _cfg()
    ref = _reference(cfg, SPEC)
    inj = FaultInjector(exhaust_pool_at=(1, 2, 5))
    reqs, eng = _run(cfg, SPEC, pool_pages=POOL, preempt="swap", inj=inj)
    _assert_parity(reqs, ref)
    assert inj.exhaust_fired == {1, 2, 5}


def test_combined_faults_recover():
    ops.use_kernels(False)
    cfg = _cfg()
    ref = _reference(cfg, SPEC)
    inj = FaultInjector(fail_at=(2, 6), corrupt_swap=(1,),
                        exhaust_pool_at=(4,))
    reqs, eng = _run(cfg, SPEC, pool_pages=POOL, preempt="swap", inj=inj)
    _assert_parity(reqs, ref)
    assert eng.fabric_stats.faults_recovered == 2
    assert eng.fabric_stats.bursts_retried >= 1


# ---------------------------------------------------------------------------
# nightly churn sweep: preemption on/off × swap/recompute
# ---------------------------------------------------------------------------

_SWEEP = st.lists(
    st.tuples(st.integers(0, 5), st.integers(1, 11), st.integers(1, 5),
              st.integers(0, 2)),
    min_size=2, max_size=6)


@pytest.mark.slow
@settings(max_examples=20, deadline=None)
@given(spec=_SWEEP, mode=st.sampled_from(["off", "swap", "recompute"]),
       page_size=st.sampled_from([2, 4, 8]))
def test_property_preemption_churn_parity(spec, mode, page_size):
    """Random arrivals × priority classes × preemption policy (the nightly
    axis): always bit-identical to the unconstrained reference, always full
    reclamation.  The pool is sized for one worst-case reach (len 11 + 5
    new against t_max 24) so progress is guaranteed even with preemption
    off."""
    ops.use_kernels(False)
    cfg = _cfg()
    ref = _reference(cfg, spec)
    pool_pages = -(-16 // page_size)
    reqs, eng = _run(cfg, spec, pool_pages=pool_pages, preempt=mode,
                     page_size=page_size, max_steps=600)
    _assert_parity(reqs, ref)
    assert eng.kv.pool.pages_in_use == 0
    assert eng._swap_pages_used == 0 and not eng._swapped
    if mode == "off":
        assert eng.fabric_stats.preemptions == 0
