import jax
import jax.numpy as jnp
import numpy as np
import pytest

from hypothesis_compat import given, settings, st

from repro.core import (medusa_transpose, medusa_transpose_cycle_accurate,
                        medusa_swap_minor, read_network_medusa,
                        write_network_medusa, read_network_oracle,
                        write_network_oracle, read_network_crossbar,
                        write_network_crossbar, transposition_latency_cycles,
                        port_stream, port_major_view, Interconnect)


@pytest.mark.parametrize("n", [2, 4, 8, 16])
def test_cycle_accurate_is_transpose(n):
    i = jnp.arange(n * n * 2.0).reshape(n, n, 2)
    o = medusa_transpose_cycle_accurate(i)
    np.testing.assert_allclose(np.asarray(o), np.asarray(jnp.swapaxes(i, 0, 1)))


def test_cycle_accurate_completes_in_n_cycles():
    n = 8
    i = jnp.arange(n * n * 1.0).reshape(n, n, 1)
    _, trace = medusa_transpose_cycle_accurate(i, return_trace=True)
    assert len(trace) == n == transposition_latency_cycles(n)
    # after cycle c < n the transpose is NOT yet complete (pipeline fills)
    partial = trace[n // 2][2]
    assert not np.allclose(np.asarray(partial),
                           np.asarray(jnp.swapaxes(i, 0, 1)))


@pytest.mark.parametrize("n", [2, 4, 8, 16, 32, 64, 128])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_exchange_network_transpose(n, dtype):
    x = jax.random.normal(jax.random.PRNGKey(n), (n, n, 2)).astype(dtype)
    np.testing.assert_array_equal(
        np.asarray(medusa_transpose(x, 0, 1)), np.asarray(jnp.swapaxes(x, 0, 1)))


@pytest.mark.parametrize("impl", ["medusa", "crossbar", "oracle"])
@pytest.mark.parametrize("n,g,w", [(4, 2, 3), (8, 4, 16), (16, 1, 1)])
def test_interconnect_read_write_roundtrip(impl, n, g, w):
    lines = jax.random.normal(jax.random.PRNGKey(0), (g * n, n, w))
    ic = Interconnect(n_ports=n, impl=impl)
    banked = ic.read(lines)
    np.testing.assert_allclose(np.asarray(banked),
                               np.asarray(read_network_oracle(lines, n)))
    back = ic.write(banked)
    np.testing.assert_allclose(np.asarray(back), np.asarray(lines))


def test_banked_semantics_deep_narrow():
    # banked[g, y, p] = lines[g*N + p, y]: port p owns lane column p
    n, g, w = 4, 3, 2
    lines = jnp.arange(g * n * n * w, dtype=jnp.float32).reshape(g * n, n, w)
    banked = read_network_medusa(lines, n)
    for p in range(n):
        np.testing.assert_allclose(np.asarray(port_stream(banked, p)),
                                   np.asarray(lines[p::n]))
    pm = port_major_view(banked)
    assert pm.shape == (n, g, n, w)


@settings(max_examples=20, deadline=None)
@given(st.sampled_from([2, 4, 8, 16]), st.integers(1, 4), st.integers(1, 5))
def test_read_write_identity_property(n, g, w):
    lines = jnp.arange(g * n * n * w, dtype=jnp.float32).reshape(g * n, n, w)
    np.testing.assert_allclose(
        np.asarray(write_network_medusa(read_network_medusa(lines, n), n)),
        np.asarray(lines))
    np.testing.assert_allclose(
        np.asarray(write_network_crossbar(read_network_crossbar(lines, n), n)),
        np.asarray(lines))


@settings(max_examples=25, deadline=None)
@given(st.integers(1, 130), st.integers(1, 130))
def test_swap_minor_rectangular(r, c):
    x = jax.random.normal(jax.random.PRNGKey(r * 131 + c), (2, r, c))
    np.testing.assert_allclose(np.asarray(medusa_swap_minor(x)),
                               np.asarray(jnp.swapaxes(x, -1, -2)))


def test_transpose_involution():
    x = jax.random.normal(jax.random.PRNGKey(0), (16, 16, 4))
    np.testing.assert_array_equal(
        np.asarray(medusa_transpose(medusa_transpose(x, 0, 1), 0, 1)),
        np.asarray(x))
