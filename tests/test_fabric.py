"""Fabric parity: every impl, every consumer path, bit-identical results.

The refactor's acceptance bar: ``medusa`` / ``crossbar`` / ``oracle`` are
drop-in replacements through every migrated consumer — the rectangular
layout engine, the burst-scheduled multi-stream round-trip, and the serving
engine's paged KV read-back.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_fabric, get_smoke
from repro.configs.base import FabricConfig
from repro.core.transpose import read_network_oracle
from repro.data.pipeline import batch_lines
from repro.fabric import BurstScheduler, Fabric, PagedKVCache
from repro.kernels import ops
from repro.models import api
from repro.serving import Request, ServingEngine

IMPLS = ("medusa", "crossbar", "oracle")
KEY = jax.random.PRNGKey(7)


# ---------------------------------------------------------------------------
# consumer 1: rectangular layout engine
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("impl", IMPLS)
@pytest.mark.parametrize("r,c", [(3, 5), (8, 8), (16, 5), (1, 7), (33, 130)])
def test_swap_minor_parity(impl, r, c):
    x = jax.random.normal(KEY, (2, r, c))
    out = Fabric.make(8, impl).swap_minor(x)
    np.testing.assert_array_equal(np.asarray(out),
                                  np.asarray(jnp.swapaxes(x, -1, -2)))


# ---------------------------------------------------------------------------
# consumer 2: burst-scheduled multi-stream round-trip
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("pack", ("packed", "pad"))
@pytest.mark.parametrize("impl", IMPLS)
def test_burst_scheduler_multi_stream_roundtrip(impl, pack):
    """KV read + weight stream + MoE dispatch + batch staging share one
    network invocation, and each comes back bit-identical to its own
    per-stream transfer, under both burst layouts; the write network
    inverts."""
    n = 4
    fab = Fabric.make(n, impl, pack=pack)
    sched = BurstScheduler(fab)
    streams = {
        "kv_read": jax.random.normal(KEY, (8 * n, n, 16)),
        "weight_stream": jax.random.normal(jax.random.fold_in(KEY, 1),
                                           (2 * n, n, 5)),
        "moe_dispatch": jax.random.normal(jax.random.fold_in(KEY, 2),
                                          (4 * n, n)),
        "batch_stage": jnp.asarray(
            batch_lines(np.arange(64, dtype=np.int32).reshape(2, 32), n),
            jnp.float32),
    }
    for name, lines in streams.items():
        sched.enqueue_read(name, lines)
    out = sched.flush()
    assert sched.stats.network_calls == 1          # one burst, all streams
    assert sched.stats.streams_served == len(streams)
    for name, lines in streams.items():
        np.testing.assert_array_equal(
            np.asarray(out[name]),
            np.asarray(read_network_oracle(lines, n)))
    for name in streams:
        sched.enqueue_write(name, out[name])
    back = sched.flush()
    assert sched.stats.network_calls == 2
    for name, lines in streams.items():
        np.testing.assert_array_equal(np.asarray(back[name]),
                                      np.asarray(lines))


def test_burst_scheduler_rejects_bad_geometry():
    sched = BurstScheduler(Fabric.make(4, "oracle"))
    with pytest.raises(ValueError):
        sched.enqueue_read("bad", jnp.zeros((7, 4)))       # L not multiple
    with pytest.raises(ValueError):
        sched.enqueue_read("bad", jnp.zeros((8, 3)))       # wrong line width
    with pytest.raises(ValueError):
        sched.enqueue_write("bad", jnp.zeros((2, 4, 3)))   # not banked


def test_burst_scheduler_rejects_duplicate_stream_names():
    """Results are keyed by name — a duplicate (even read vs write) would
    silently shadow one stream's data, so enqueue refuses it."""
    sched = BurstScheduler(Fabric.make(4, "oracle"))
    sched.enqueue_read("kv", jnp.zeros((4, 4)))
    with pytest.raises(ValueError, match="already queued"):
        sched.enqueue_read("kv", jnp.zeros((4, 4)))
    with pytest.raises(ValueError, match="already queued"):
        sched.enqueue_write("kv", jnp.zeros((1, 4, 4)))
    sched.flush()
    sched.enqueue_read("kv", jnp.zeros((4, 4)))            # fresh flush: ok


def test_burst_scheduler_empty_flush():
    """A flush with nothing queued is a no-op burst, not an error."""
    sched = BurstScheduler(Fabric.make(4, "medusa"))
    assert sched.flush() == {}
    assert sched.stats.flushes == 1
    assert sched.stats.network_calls == 0
    assert sched.stats.streams_served == 0


def test_burst_scheduler_issue_commit_ordering():
    """The pipeline is one deep: commit() needs a matching issue(), a second
    issue() needs the first burst committed — but the *next* burst's streams
    may stage while one is in flight (the §III-C double buffer)."""
    sched = BurstScheduler(Fabric.make(4, "oracle"))
    with pytest.raises(RuntimeError, match="without a matching issue"):
        sched.commit()
    x = jnp.arange(16, dtype=jnp.float32).reshape(4, 4)
    sched.enqueue_read("a", x)
    sched.issue()
    sched.enqueue_read("b", 2 * x)     # stages behind the in-flight burst
    with pytest.raises(RuntimeError, match="already in flight"):
        sched.issue()
    out = sched.commit()
    assert set(out) == {"a"}
    with pytest.raises(RuntimeError, match="without a matching issue"):
        sched.commit()                 # committed burst is gone
    out2 = sched.flush()               # the staged stream was not dropped
    assert set(out2) == {"b"}
    np.testing.assert_array_equal(np.asarray(out2["b"]),
                                  np.asarray(read_network_oracle(2 * x, 4)))
    assert sched.stats.flushes == 2 and sched.stats.network_calls == 2


@pytest.mark.parametrize("pack", ("packed", "pad"))
def test_burst_scheduler_mixed_dtype_splits_bursts(pack):
    """Streams of different dtypes cannot share a burst bit-identically, so
    the scheduler keeps one network call per dtype — and each stream still
    returns bit-identical to its own transfer."""
    n = 4
    sched = BurstScheduler(Fabric.make(n, "medusa", pack=pack))
    streams = {
        "kv_bf16": jax.random.normal(KEY, (2 * n, n, 8)).astype(jnp.bfloat16),
        "wt_bf16": jax.random.normal(jax.random.fold_in(KEY, 1),
                                     (n, n, 3)).astype(jnp.bfloat16),
        "stage_i32": jnp.arange(2 * n * n * 5, dtype=jnp.int32
                                ).reshape(2 * n, n, 5),
        "acc_f32": jax.random.normal(jax.random.fold_in(KEY, 2), (n, n)),
    }
    for name, x in streams.items():
        sched.enqueue_read(name, x)
    out = sched.flush()
    assert sched.stats.flushes == 1
    assert sched.stats.network_calls == 3          # bf16 / int32 / f32
    assert sched.stats.streams_served == 4
    for name, x in streams.items():
        assert out[name].dtype == x.dtype
        np.testing.assert_array_equal(
            np.asarray(out[name], np.float32),
            np.asarray(read_network_oracle(x, n), np.float32))


def test_port_spec_records_packed_extents():
    """Each stream's PortSpec carries its (offset, words) extent on the
    packed burst's word axis — cumulative per direction and dtype, in
    enqueue order (the per-port head/tail pointers)."""
    n = 4
    sched = BurstScheduler(Fabric.make(n, "oracle"))
    a = sched.enqueue_read("a", jnp.zeros((2 * n, n, 8)))    # 2 groups x 8
    b = sched.enqueue_read("b", jnp.zeros((n, n, 3)))        # 1 group x 3
    c = sched.enqueue_read("c", jnp.zeros((n, n)))           # 1 group x 1
    w = sched.enqueue_write("w", jnp.zeros((2, n, n, 5)))    # separate axis
    assert (a.offset, a.words) == (0, 16)
    assert (b.offset, b.words) == (16, 3)
    assert (c.offset, c.words) == (19, 1)
    assert (w.offset, w.words) == (0, 10)
    assert a.direction == "read" and w.direction == "write"
    sched.flush()


@pytest.mark.parametrize("impl", IMPLS)
def test_packed_pad_unscheduled_parity(impl):
    """The acceptance sweep: packed vs pad vs unscheduled (per-stream
    ``Fabric.read``) are bit-identical on the mixed-width workload, and the
    packed layout moves zero padding while pad moves the fill."""
    n = 4
    fab = Fabric.make(n, impl)
    streams = {
        "kv": jax.random.normal(KEY, (4 * n, n, 16)).astype(jnp.bfloat16),
        "wt": jax.random.normal(jax.random.fold_in(KEY, 1),
                                (2 * n, n, 4)).astype(jnp.bfloat16),
        "st": jax.random.normal(jax.random.fold_in(KEY, 2),
                                (n, n)).astype(jnp.bfloat16),
    }
    unscheduled = {name: fab.read(x) for name, x in streams.items()}
    outs = {}
    for pack in ("packed", "pad"):
        sched = BurstScheduler(fab, pack=pack)
        for name, x in streams.items():
            sched.enqueue_read(name, x)
        outs[pack] = sched.flush()
        assert sched.stats.network_calls == 1
        assert sched.stats.words_moved == sum(
            int(np.prod(x.shape)) for x in streams.values())
        # pad-to-widest fill: wt pads 12 of 16 words over 2n lines, st 15
        assert sched.stats.words_padded == (0 if pack == "packed" else
                                            2 * n * n * 12 + n * n * 15)
        for name in streams:
            np.testing.assert_array_equal(
                np.asarray(outs[pack][name], np.float32),
                np.asarray(unscheduled[name], np.float32))


# ---------------------------------------------------------------------------
# consumer 3: KV layout engine + paged serving read-back
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("impl", IMPLS)
def test_kv_port_major_parity(impl):
    c = jax.random.normal(KEY, (2, 12, 4, 8))
    out = Fabric.make(4, impl).kv_port_major(c)
    np.testing.assert_array_equal(np.asarray(out),
                                  np.asarray(jnp.swapaxes(c, 1, 2)))


@pytest.mark.parametrize("impl", IMPLS)
def test_paged_engine_matches_greedy_reference(impl):
    """The engine on the paged KV layout (small pages, forced remap) decodes
    the same greedy tokens as one-shot generation, per fabric impl."""
    ops.use_kernels(False)
    cfg = dataclasses.replace(get_smoke("starcoder2-15b"), dtype="float32",
                              kv_layout=impl)
    params = api.init_params(cfg, KEY)
    prompts = [np.asarray(jax.random.randint(jax.random.fold_in(KEY, i),
                                             (5 + 3 * i,), 0, cfg.vocab_size),
                          np.int32) for i in range(3)]
    refs = []
    for pr in prompts:
        out = api.greedy_generate(params, jnp.asarray(pr)[None], cfg,
                                  steps=4, t_max=32)
        first_logits, _ = api.prefill_fn(
            params, {"tokens": jnp.asarray(pr)[None]}, cfg, 32)
        refs.append([int(np.argmax(np.asarray(first_logits[0, -1])))]
                    + np.asarray(out[0]).tolist())

    eng = ServingEngine(cfg, params, max_slots=2, t_max=32, page_size=4)
    reqs = [Request(i, pr, max_new_tokens=5) for i, pr in enumerate(prompts)]
    for r in reqs:
        eng.submit(r)
    eng.run_to_completion(max_steps=64)
    for r, ref in zip(reqs, refs):
        assert r.done
        assert r.generated == ref, (impl, r.rid, r.generated, ref)
    # paged admission moved strictly less data than the dense splice would
    assert eng.kv.tokens_moved < eng.kv.tokens_moved_dense
    assert eng.kv.table.occupancy == 0.0           # all slots retired


def test_paged_cache_page_accounting():
    cfg = dataclasses.replace(get_smoke("starcoder2-15b"), dtype="float32")
    caches = api.init_cache(cfg, 2, 32)
    kv = PagedKVCache(caches, max_slots=2, t_max=32, page_size=8)
    req = api.init_cache(cfg, 1, 32)
    kv.refill(0, req, n_tokens=9)                  # 2 pages of 8
    assert kv.table.used[0] == 2
    assert kv.tokens_moved == 16 and kv.tokens_moved_dense == 32
    kv.extend(0, 16)                               # decode reached pos 16
    assert kv.table.used[0] == 3
    kv.free(0)
    assert kv.table.used[0] == 0


# ---------------------------------------------------------------------------
# config / registry flow
# ---------------------------------------------------------------------------

def test_fabric_flows_through_registry():
    fab = get_fabric("gemma3-12b")
    cfg = get_smoke("gemma3-12b")
    assert fab.n_ports == 8 and fab.impl == "medusa"
    assert cfg.resolved_fabric.n_ports == cfg.n_kv_heads
    assert cfg.resolved_fabric.lane_width == cfg.resolved_head_dim
    # explicit fabric wins over the derived one
    explicit = dataclasses.replace(cfg, fabric=FabricConfig(
        n_ports=2, lane_width=16, impl="oracle"))
    assert explicit.resolved_fabric.impl == "oracle"
    assert Fabric.for_model(explicit).n_ports == 2


def test_explicit_fabric_impl_drives_decode_dispatch():
    """An explicit FabricConfig is the single switch: impl='fused' through
    ``ModelConfig.fabric`` (with kv_layout left at its default) must take
    the fused decode path and stay value-identical to the oracle."""
    ops.use_kernels(False)
    base = dataclasses.replace(get_smoke("starcoder2-15b"), dtype="float32")
    params = api.init_params(base, KEY)
    toks = jax.random.randint(KEY, (2, 9), 0, base.vocab_size)

    def decode_logits(cfg):
        _, caches = api.prefill_fn(params, {"tokens": toks[:, :8]}, cfg, 10)
        logits, _ = api.decode_fn(params, toks[:, 8:9], caches, 8, cfg)
        return np.asarray(logits[:, 0])

    oracle = decode_logits(dataclasses.replace(base, kv_layout="oracle"))
    explicit = dataclasses.replace(base, fabric=FabricConfig(
        n_ports=base.n_kv_heads, lane_width=base.resolved_head_dim,
        impl="fused"))
    assert explicit.kv_layout == "medusa"          # stale string is ignored
    np.testing.assert_allclose(decode_logits(explicit), oracle, atol=2e-4)


def test_fabric_config_validates():
    with pytest.raises(ValueError):
        FabricConfig(impl="warp").validate()
    with pytest.raises(ValueError):
        FabricConfig(n_ports=0).validate()
    with pytest.raises(ValueError):
        FabricConfig(page_size=0).validate()
    assert FabricConfig(n_ports=32, lane_width=16).line_width == 512
