"""The profiling instrument: per-op breakdown sums match analyze_hlo."""
import jax
import jax.numpy as jnp

from repro.launch.hlo_analysis import analyze_hlo
from repro.launch.profile import breakdown


def test_breakdown_totals_match_analyzer():
    w = jnp.ones((64, 64))

    def f(x):
        y, _ = jax.lax.scan(lambda c, _: (jnp.tanh(c @ w), None), x, None,
                            length=6)
        return y.sum()

    txt = jax.jit(f).lower(jnp.ones((64, 64))).compile().as_text()
    costs, totals = breakdown(txt)
    ref = analyze_hlo(txt)
    assert abs(totals["flops"] - ref.flops) < 1e-6
    assert abs(totals["bytes"] - ref.bytes) / max(ref.bytes, 1) < 1e-6
    assert abs(totals["collective_bytes"] - ref.collective_bytes) < 1e-6
    assert costs[0].bytes >= costs[-1].bytes        # sorted
