"""Medusa-schedule shard_map MoE ≡ GSPMD MoE (ample capacity, 8 ranks)."""
import os
import subprocess
import sys

ROOT = os.path.join(os.path.dirname(__file__), "..")


def test_shardmap_moe_matches_gspmd():
    code = """
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.configs.base import ModelConfig, MoEConfig
from repro.models.moe import moe_params, moe_apply
from repro.models.moe_shardmap import moe_apply_shardmap, shard_expert_params

N = 8
cfg = ModelConfig(name="t", family="moe", n_layers=1, d_model=32, n_heads=2,
                  n_kv_heads=2, d_ff=0, vocab_size=64,
                  moe=MoEConfig(n_experts=16, top_k=2, expert_d_ff=64,
                                capacity_factor=16.0))
key = jax.random.PRNGKey(0)
p = moe_params(key, cfg, jnp.float32)
x = jax.random.normal(jax.random.PRNGKey(1), (N * 2, 4, 32))

ref = moe_apply(p, x, cfg)                      # GSPMD/pjit layer, unsharded

from repro.launch.mesh import compat_shard_map, make_mesh
mesh = make_mesh((N,), ("model",))

def body(p_full, xb):
    rank = jax.lax.axis_index("model")
    p_loc = shard_expert_params(p_full, rank, N, cfg)
    return moe_apply_shardmap(p_loc, xb, cfg, "model")

out = compat_shard_map(body, mesh=mesh, in_specs=(P(), P("model")),
                    out_specs=P("model"), check_vma=False)(p, x)
np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-4)

# and the lowering uses only rotations — no all-to-all, no payload scatter
txt = jax.jit(compat_shard_map(body, mesh=mesh, in_specs=(P(), P("model")),
                            out_specs=P("model"), check_vma=False)
              ).lower(p, x).compile().as_text()
n_perm = txt.count(" collective-permute(") + txt.count(" collective-permute-start(")
assert n_perm >= 2 * (N - 1), n_perm           # fwd + reverse rings
assert " all-to-all(" not in txt and " all-to-all-start(" not in txt
print("OK", n_perm)
"""
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               PYTHONPATH=os.path.join(ROOT, "src"))
    r = subprocess.run([sys.executable, "-c", code], env=env,
                       capture_output=True, text=True, timeout=420)
    assert "OK" in r.stdout, (r.stdout[-1500:], r.stderr[-1500:])


def test_shardmap_moe_trains():
    """Gradients flow through the 2(N-1) ring rotations: a tiny MoE regression
    trained end-to-end under the medusa dispatch schedule reduces loss."""
    code = """
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.configs.base import ModelConfig, MoEConfig
from repro.models.moe import moe_params
from repro.models.moe_shardmap import moe_apply_shardmap, shard_expert_params

N = 8
cfg = ModelConfig(name="t", family="moe", n_layers=1, d_model=16, n_heads=2,
                  n_kv_heads=2, d_ff=0, vocab_size=64,
                  moe=MoEConfig(n_experts=8, top_k=2, expert_d_ff=32,
                                capacity_factor=8.0))
key = jax.random.PRNGKey(0)
p = moe_params(key, cfg, jnp.float32)
from repro.launch.mesh import compat_shard_map, make_mesh
mesh = make_mesh((N,), ("model",))
x = jax.random.normal(jax.random.PRNGKey(1), (N * 2, 4, 16))
target = jnp.tanh(x @ jax.random.normal(jax.random.PRNGKey(2), (16, 16)))

def loss_fn(p_full, xb, tb):
    rank = jax.lax.axis_index("model")
    p_loc = shard_expert_params(p_full, rank, N, cfg)
    out = moe_apply_shardmap(p_loc, xb, cfg, "model")
    return jax.lax.pmean(jnp.mean((out - tb) ** 2), "model")

smap = compat_shard_map(loss_fn, mesh=mesh, in_specs=(P(), P("model"), P("model")),
                     out_specs=P(), check_vma=False)
step = jax.jit(jax.value_and_grad(lambda p_: smap(p_, x, target)))
losses = []
for i in range(40):
    l, g = step(p)
    p = jax.tree.map(lambda a, b: a - 0.3 * b, p, g)
    losses.append(float(l))
assert losses[-1] < 0.75 * losses[0], losses[::8]
print("OK", round(losses[0], 4), "->", round(losses[-1], 4))
"""
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               PYTHONPATH=os.path.join(ROOT, "src"))
    r = subprocess.run([sys.executable, "-c", code], env=env,
                       capture_output=True, text=True, timeout=420)
    assert "OK" in r.stdout, (r.stdout[-1500:], r.stderr[-1500:])
