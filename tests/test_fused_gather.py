"""Fused page-table gather: the pool's logical→physical indirection as part
of the fabric contract (sparse-extent streams) instead of a consumer-side
postprocess on the banked full pool.

The acceptance bar:

* kernel level — the fused gather/scatter burst kernels (indices as a
  scalar-prefetched operand) are bit-identical to take/scatter around the
  exchange network, including sentinel padding rows and odd word tiles;
* scheduler level — sparse-extent streams are bit-identical to their dense
  take-after equivalents under pack × word_fold × {kernel, unrolled}, and
  the traffic census counts live words, not pool words;
* decode level — the fused scheduled step, the gather-after-burst scheduled
  step and the per-layer paged fallback agree bit-for-bit on logits AND the
  written-back pools, over churny page tables (holes, ``-1`` unmapped rows,
  reused non-contiguous physical pages);
* engine level — fused on/off produce identical tokens while ``words_moved``
  drops to the live-frame count and ``gather_fused_bursts`` distinguishes
  the contracts in the printed census;
* admission — the fused sparse-write install is bit-identical to the
  per-layer splice and widens burst eligibility to odd spans.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.fabric import BurstScheduler, Fabric, PagedKVCache, SchedulerStats
from repro.kernels import ops
from repro.kernels.medusa_transpose import (_pick_word_tile,
                                            gather_burst_network_tiles,
                                            scatter_burst_network_tiles)
from repro.models import api, common as cm, lm
from repro.serving import Request, ServingEngine

from repro.fabric.scheduler import FRAME_SENTINEL as SENTINEL
from tests.hypothesis_compat import given, settings, st

KEY = jax.random.PRNGKey(11)


def _cfg():
    return dataclasses.replace(get_smoke("starcoder2-15b"), dtype="float32")


_PARAMS = {}


def _params(cfg):
    if cfg.name not in _PARAMS:
        _PARAMS[cfg.name] = api.init_params(cfg, KEY)
    return _PARAMS[cfg.name]


# ---------------------------------------------------------------------------
# kernel level
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n", (2, 4, 8))
@pytest.mark.parametrize("word_tile", (0, 3))
def test_gather_kernel_matches_take_then_network(n, word_tile):
    """One fused launch == take (sentinels → zero frames) + banked
    transpose, for power-of-two N and both whole-burst and odd dividing
    word tiles."""
    l, w, k = 5 * n, 6, 2 * n
    lines = jax.random.normal(jax.random.fold_in(KEY, n), (l, n, w),
                              jnp.float32)
    idx = np.full((k,), SENTINEL, np.int32)
    perm = np.random.RandomState(n).permutation(l)
    idx[: k - 2] = perm[: k - 2]                   # 2 sentinel pads
    idx = jnp.asarray(idx)
    out = gather_burst_network_tiles(lines, idx, n, word_tile=word_tile)
    ref = jnp.take(lines, idx, axis=0, mode="fill",
                   fill_value=0).reshape(k // n, n, n, w).swapaxes(1, 2)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


@pytest.mark.parametrize("n", (2, 4, 8))
def test_scatter_kernel_matches_network_then_scatter(n):
    """The aliased scatter launch == write network + at[].set(drop):
    addressed rows land, sentinel rows drop, untouched rows keep their
    frames bit-for-bit."""
    l, w, k = 6 * n, 4, 2 * n
    banked = jax.random.normal(jax.random.fold_in(KEY, n), (k // n, n, n, w),
                               jnp.float32)
    pool = jax.random.normal(jax.random.fold_in(KEY, 100 + n), (l, n, w),
                             jnp.float32)
    idx = np.full((k,), SENTINEL, np.int32)
    idx[: k - 1] = np.random.RandomState(n).permutation(l)[: k - 1]
    idx = jnp.asarray(idx)
    out = scatter_burst_network_tiles(banked, idx, pool, n)
    lines = banked.swapaxes(1, 2).reshape(k, n, w)
    ref = pool.at[idx].set(lines, mode="drop")
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))
    # untouched rows really are the original pool
    untouched = sorted(set(range(l))
                       - set(np.asarray(idx[: k - 1]).tolist()))
    np.testing.assert_array_equal(np.asarray(out)[untouched],
                                  np.asarray(pool)[untouched])


def test_pick_word_tile_respects_gather_block_shape():
    """Regression (odd word_tile × sparse extent): the gather-operand mode
    must return a divisor of the frame word count — a padded edge tile
    would read/write past an indexed frame's extent — while the dense mode
    keeps its padded fallback; a non-dividing explicit tile is a loud
    error, not a silent misread."""
    assert _pick_word_tile(4099) == 2050                  # pad fallback
    assert _pick_word_tile(4099, divisor=True) == 1       # prime: worst case
    assert 4100 % _pick_word_tile(4100, divisor=True) == 0
    w = 6000                                              # no divisor in (2048, 4096]
    t = _pick_word_tile(w, divisor=True)
    assert w % t == 0 and t <= 4096
    lines = jnp.zeros((4, 4, 6), jnp.float32)
    idx = jnp.zeros((4,), jnp.int32)
    with pytest.raises(ValueError, match="word_tile"):
        gather_burst_network_tiles(lines, idx, 4, word_tile=4)
    with pytest.raises(ValueError, match="word_tile"):
        scatter_burst_network_tiles(jnp.zeros((1, 4, 4, 6), jnp.float32),
                                    idx, lines, 4, word_tile=4)


# ---------------------------------------------------------------------------
# scheduler level
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("pack", ("packed", "pad"))
@pytest.mark.parametrize("fold", (1, 2, "auto"))
@pytest.mark.parametrize("kernels", (False, True))
def test_scheduler_sparse_streams_parity(pack, fold, kernels):
    """Sparse-extent reads/writes mixed with dense streams are bit-identical
    to take-after-the-fact across every pack × fold × kernel combination,
    and the census counts live words for them."""
    n, d, frames, k = 4, 8, 32, 12
    pool = jax.random.normal(KEY, (frames, n, d), jnp.bfloat16)
    dense = jax.random.normal(jax.random.fold_in(KEY, 1), (2 * n, n, 6),
                              jnp.bfloat16)
    banked_upd = jax.random.normal(jax.random.fold_in(KEY, 2),
                                   (k // n, n, n, d), jnp.bfloat16)
    idx = np.full((k,), SENTINEL, np.int32)
    idx[:10] = np.random.RandomState(0).permutation(frames)[:10]
    idx = jnp.asarray(idx)
    prev = ops.kernels_enabled()
    ops.use_kernels(kernels)
    try:
        stats = SchedulerStats()
        sched = BurstScheduler(Fabric.make(n, "medusa", pack=pack,
                                           word_fold=fold), stats=stats)
        sched.enqueue_read("kv", pool, gather=idx)
        sched.enqueue_read("wt", dense)
        sched.enqueue_write("kv_w", banked_upd, scatter=idx, into=pool)
        out = sched.flush()
    finally:
        ops.use_kernels(prev)
    ref_read = jnp.take(pool, idx, axis=0, mode="fill",
                        fill_value=0).reshape(k // n, n, n, d).swapaxes(1, 2)
    ref_pool = pool.at[idx].set(
        banked_upd.swapaxes(1, 2).reshape(k, n, d), mode="drop")
    np.testing.assert_array_equal(*map(np.asarray, (out["kv"], ref_read)))
    np.testing.assert_array_equal(*map(np.asarray, (out["kv_w"], ref_pool)))
    live = 2 * (k * n * d)                        # read + write live words
    assert stats.words_live == live
    assert stats.words_moved == live + 2 * n * n * 6
    assert stats.gather_fused_bursts >= 1
    # the spec records the sparse extent: live words vs the pool extent
    assert stats.words_padded == 0 or pack == "pad"


def test_portspec_sparse_extent_fields():
    """The sparse-extent mode is visible on the PortSpec: live ``words``
    plus the ``pool_words`` the gather-after fallback would have moved."""
    n, d, frames, k = 4, 8, 32, 8
    pool = jnp.zeros((frames, n, d), jnp.float32)
    idx = jnp.zeros((k,), jnp.int32)
    sched = BurstScheduler(Fabric.make(n, "medusa"))
    spec = sched.enqueue_read("kv", pool, gather=idx)
    assert spec.gathered and spec.words == (k // n) * d
    assert spec.pool_words == (frames // n) * d
    dense_spec = sched.enqueue_read("wt", jnp.zeros((n, n, 3), jnp.float32))
    assert not dense_spec.gathered and dense_spec.pool_words == 0


# ---------------------------------------------------------------------------
# decode level: fused vs gather-after vs per-layer, churny tables
# ---------------------------------------------------------------------------

def test_page_live_plan_rejects_non_prefix_rows():
    """The live plan (and the sparse-extent index contract: non-negative
    frame indices or the sentinel) rests on the pool's mapped-prefix
    invariant — a hole inside a row must fail loudly, not emit
    -1-derived frame indices into a gather."""
    bad = np.array([[3, -1, 5, -1]], np.int32)     # hole at logical page 1
    with pytest.raises(ValueError, match="prefix"):
        cm.page_live_plan(bad, 4, 16, 2)
    ok = np.array([[3, 5, -1, -1]], np.int32)
    live_idx, expand, dense_pos = cm.page_live_plan(ok, 4, 16, 2)
    assert (live_idx[:8] >= 0).all() and (live_idx[8:] == SENTINEL).all()


def _pool_decode_setup(cfg, table, pos, page_size, t_alloc, pool_pages):
    """Pool caches with random (arbitrary) frame content + the step inputs."""
    b = table.shape[0]
    caches = api.init_cache(cfg, b, t_alloc, pool_pages=pool_pages,
                            page_size=page_size)
    leaves, treedef = jax.tree_util.tree_flatten(caches)
    leaves = [jax.random.normal(jax.random.fold_in(KEY, 200 + i),
                                leaf.shape, leaf.dtype)
              for i, leaf in enumerate(leaves)]
    caches = jax.tree_util.tree_unflatten(treedef, leaves)
    token = jax.random.randint(jax.random.fold_in(KEY, 300), (b, 1), 0,
                               cfg.vocab_size)
    return caches, token, jnp.asarray(pos, jnp.int32)


def _decode_three_ways(cfg, caches, token, pos, table, ps, t_alloc):
    pt = jnp.asarray(table)
    plan = tuple(jnp.asarray(a) for a in cm.page_live_plan(
        table, ps, t_alloc, cfg.resolved_fabric.n_ports))
    ref = api.decode_fn(_params(cfg), token, caches, pos, cfg,
                        page_table=pt, page_size=ps, t_depth=t_alloc)
    sched = BurstScheduler(Fabric(cfg.resolved_fabric))
    ga = api.decode_fn(_params(cfg), token, caches, pos, cfg, sched=sched,
                       page_table=pt, page_size=ps, t_depth=t_alloc)
    sched = BurstScheduler(Fabric(cfg.resolved_fabric))
    fused = api.decode_fn(_params(cfg), token, caches, pos, cfg, sched=sched,
                          page_table=pt, page_size=ps, t_depth=t_alloc,
                          live_plan=plan)
    return ref, ga, fused


def _assert_step_equal(a, b):
    np.testing.assert_array_equal(np.asarray(a[0]), np.asarray(b[0]))
    jax.tree.map(lambda x, y: np.testing.assert_array_equal(
        np.asarray(x), np.asarray(y)), a[1], b[1])


@pytest.mark.parametrize("pack", ("packed", "pad"))
@pytest.mark.parametrize("fold", (1, 2, "auto"))
@pytest.mark.parametrize("kernels", (False, True))
def test_decode_fused_vs_fallbacks_churny_table(pack, fold, kernels):
    """A churny page table — a hole slot (all ``-1``), a partially-mapped
    slot, reused non-contiguous physical pages — decodes bit-identically
    through the fused contract, the gather-after-burst scheduled step and
    the per-layer paged fallback: logits AND written-back pools."""
    cfg = _cfg()
    cfg = dataclasses.replace(
        cfg, fabric=dataclasses.replace(cfg.resolved_fabric, pack=pack,
                                        word_fold=fold))
    ps, t_alloc, pool_pages = 3, 16, 14            # odd page size, slack pool
    table = np.array([[5, 2, 9, -1, -1, -1],       # non-contiguous physmap
                      [-1, -1, -1, -1, -1, -1],    # hole: retired slot
                      [0, 13, 7, 4, -1, -1]], np.int32)
    pos = [4, 0, 10]
    caches, token, pos = _pool_decode_setup(cfg, table, pos, ps, t_alloc,
                                            pool_pages)
    prev = ops.kernels_enabled()
    ops.use_kernels(kernels)
    try:
        ref, ga, fused = _decode_three_ways(cfg, caches, token, pos, table,
                                            ps, t_alloc)
    finally:
        ops.use_kernels(prev)
    _assert_step_equal(ga, ref)
    _assert_step_equal(fused, ref)


@pytest.mark.slow
@settings(max_examples=20, deadline=None)
@given(data=st.data())
def test_property_fused_decode_churny_tables(data):
    """Hypothesis sweep (nightly lane): random page tables — holes, partial
    rows, shuffled physical pages — × pack × fold × kernel, fused vs
    gather-after vs per-layer bit-parity on logits and pools."""
    cfg = _cfg()
    pack = data.draw(st.sampled_from(("packed", "pad")), label="pack")
    fold = data.draw(st.sampled_from((1, 2, "auto")), label="fold")
    kernels = data.draw(st.booleans(), label="kernels")
    ps = data.draw(st.sampled_from((1, 3, 4)), label="page_size")
    b = data.draw(st.integers(2, 3), label="slots")
    t_alloc = 12
    pages_per_slot = -(-t_alloc // ps)
    pool_pages = b * pages_per_slot + 2
    while (pool_pages * ps) % cfg.resolved_fabric.n_ports:
        pool_pages += 1
    perm = np.random.RandomState(
        data.draw(st.integers(0, 999), label="seed")).permutation(pool_pages)
    table = np.full((b, pages_per_slot), -1, np.int32)
    pos = []
    off = 0
    for s in range(b):
        mapped = data.draw(st.integers(0, pages_per_slot), label=f"m{s}")
        table[s, :mapped] = perm[off:off + mapped]
        off += mapped
        hi = min(mapped * ps, t_alloc)
        pos.append(data.draw(st.integers(0, max(hi - 1, 0)), label=f"p{s}"))
    cfg = dataclasses.replace(
        cfg, fabric=dataclasses.replace(cfg.resolved_fabric, pack=pack,
                                        word_fold=fold))
    caches, token, pos = _pool_decode_setup(cfg, table, pos, ps, t_alloc,
                                            pool_pages)
    prev = ops.kernels_enabled()
    ops.use_kernels(kernels)
    try:
        ref, ga, fused = _decode_three_ways(cfg, caches, token, pos, table,
                                            ps, t_alloc)
    finally:
        ops.use_kernels(prev)
    _assert_step_equal(ga, ref)
    _assert_step_equal(fused, ref)


# ---------------------------------------------------------------------------
# engine level
# ---------------------------------------------------------------------------

def test_engine_fused_census_scales_with_live_frames():
    """The whole point: at low pool occupancy the fused engine's decode
    traffic is the live-frame count (words_live == words_moved for the KV
    streams), a fraction of what the gather-after engine banks, with
    identical tokens — and ``gather_fused_bursts`` tells the two apart."""
    ops.use_kernels(False)
    cfg = _cfg()
    prompt = np.asarray([3, 1, 4, 1, 5], np.int32)

    def run(fused):
        eng = ServingEngine(cfg, _params(cfg), max_slots=4, t_max=64,
                            page_size=4, fused_gather=fused)
        req = Request(0, prompt, max_new_tokens=3)
        eng.submit(req)
        eng.run_to_completion(max_steps=8)
        return req.generated, eng.fabric_stats

    gen_f, fs = run(True)
    gen_g, gs = run(False)
    assert gen_f == gen_g
    assert fs.gather_fused_bursts > 0 and gs.gather_fused_bursts == 0
    assert fs.words_live > 0 and gs.words_live == 0
    # 1 slot live of 4, page-bucketed: far under the full-pool banking
    assert fs.words_moved < gs.words_moved / 2


def test_engine_fused_matches_dense_engine_bit_identical():
    """Fused engine vs the dense (unpaged) engine: same churny workload,
    bit-identical logits on live slots (the tightest reference we have)."""
    ops.use_kernels(False)
    cfg = _cfg()
    arrivals = [(0, 5, 4), (1, 9, 3), (3, 2, 5)]
    from tests.test_paged_pool import _assert_bit_identical_runs
    eng = _assert_bit_identical_runs(cfg, arrivals)
    assert eng.fused                               # default contract engaged
    assert eng.fabric_stats.gather_fused_bursts > 0


# ---------------------------------------------------------------------------
# admission: fused sparse-write install
# ---------------------------------------------------------------------------

def _fused_kv(cfg, fabric, max_slots, t_alloc, ps, fused=True):
    pages_per_slot = -(-t_alloc // ps)
    pool_pages = max_slots * pages_per_slot
    while (pool_pages * ps) % fabric.n_ports:
        pool_pages += 1
    caches = api.init_cache(cfg, max_slots, t_alloc, pool_pages=pool_pages,
                            page_size=ps)
    return PagedKVCache(caches, max_slots, t_alloc, ps,
                        pool_pages=pool_pages,
                        paged_entries=lm.paged_entries(cfg), fabric=fabric,
                        fused_gather=fused)


@pytest.mark.parametrize("kernels", (False, True))
def test_fused_prefill_install_matches_splice(kernels):
    """The fused sparse-write admission — one scatter-indexed stream per
    leaf for the whole wave — is bit-identical to the per-layer splice,
    including an odd span the banked install had to splice (eligibility
    widens: sentinel pad rows are free)."""
    cfg = dataclasses.replace(_cfg(), n_layers=1, name="starcoder2-smoke-1lf")
    t_alloc, ps = 12, 3
    lengths = (2, 4)                     # spans 3 (odd vs N=2) and 6
    from tests.test_paged_pool import _req_caches
    rcs = _req_caches(cfg, lengths, t_alloc)
    entries = [(s, rc, ln) for s, (rc, ln) in enumerate(zip(rcs, lengths))]
    fab = Fabric(cfg.resolved_fabric)
    prev = ops.kernels_enabled()
    ops.use_kernels(kernels)
    try:
        kv_fused = _fused_kv(cfg, fab, 2, t_alloc, ps)
        kv_fused.admit_wave(entries)
        kv_splice = _fused_kv(cfg, fab, 2, t_alloc, ps)
        kv_splice.admit_wave(entries, burst=False)
    finally:
        ops.use_kernels(prev)
    # the odd-span slot rides the burst now — no splice fallback at all
    assert kv_fused.prefill_bursts == 1 and kv_fused.prefill_splices == 0
    assert np.array_equal(kv_fused.pool.table, kv_splice.pool.table)
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a), np.asarray(b)), kv_fused.caches, kv_splice.caches)
