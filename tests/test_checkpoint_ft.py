import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (save_checkpoint, restore_checkpoint, latest_step,
                              CheckpointManager)
from repro.runtime import (TrainingRunner, StragglerDetector, FaultInjector,
                           int8_quantize, int8_dequantize, ErrorFeedback,
                           compress_grads)
from repro.runtime.compression import decompress_grads


def _state(v=0.0):
    return {"params": {"w": jnp.full((4, 4), v)},
            "step": jnp.int32(v)}


def test_checkpoint_roundtrip(tmp_path):
    d = str(tmp_path)
    s = _state(3.0)
    save_checkpoint(d, 7, s, {"data_step": 7})
    assert latest_step(d) == 7
    restored, extra = restore_checkpoint(d, 7, jax.tree.map(jnp.zeros_like, s))
    np.testing.assert_array_equal(np.asarray(restored["params"]["w"]),
                                  np.asarray(s["params"]["w"]))
    assert extra["data_step"] == 7


def test_checkpoint_manager_gc(tmp_path):
    d = str(tmp_path)
    mgr = CheckpointManager(d, every=1, keep=2)
    for i in range(5):
        mgr.maybe_save(i, _state(float(i)))
    steps = sorted(int(p.split("_")[1]) for p in os.listdir(d))
    assert steps == [3, 4]


def test_checkpoint_shape_mismatch_rejected(tmp_path):
    d = str(tmp_path)
    save_checkpoint(d, 1, _state())
    bad = {"params": {"w": jnp.zeros((2, 2))}, "step": jnp.int32(0)}
    with pytest.raises(ValueError):
        restore_checkpoint(d, 1, bad)


def _make_runner(tmp_path, fail_at=()):
    """Counting 'training': state w += batch mean each step."""
    class Data:
        def batch_at(self, step):
            return {"x": np.full((2,), float(step))}

    def step_fn(state, batch):
        new = {"params": {"w": state["params"]["w"] + batch["x"].mean()},
               "step": state["step"] + 1}
        return new, {"loss": float(batch["x"].mean())}

    ckpt = CheckpointManager(str(tmp_path), every=2, keep=5)
    return TrainingRunner(step_fn, Data(), ckpt,
                          fault_injector=FaultInjector(fail_at))


def test_runner_failure_recovery_exact(tmp_path):
    """State after crash+restore equals the uninterrupted run (checkpoint/
    restart fault tolerance with a stateless-resumable pipeline)."""
    clean, _ = _make_runner(tmp_path / "a").run(_state(), 0, 10)
    faulty_runner = _make_runner(tmp_path / "b", fail_at=(5,))
    faulty, _ = faulty_runner.run(_state(), 0, 10)
    assert faulty_runner.restarts == 1
    np.testing.assert_allclose(np.asarray(faulty["params"]["w"]),
                               np.asarray(clean["params"]["w"]))


def test_straggler_detector():
    det = StragglerDetector(threshold=2.0)
    for _ in range(5):
        assert not det.observe(0.1)
    assert det.observe(0.5)          # 5x EMA → flagged
    assert det.flagged == 1
    assert not det.observe(0.1)      # EMA not poisoned


def test_int8_roundtrip_error_bound():
    g = jax.random.normal(jax.random.PRNGKey(0), (256,)) * 3
    q, scale = int8_quantize(g)
    err = np.abs(np.asarray(int8_dequantize(q, scale) - g))
    assert err.max() <= float(scale) / 2 + 1e-6


def test_error_feedback_preserves_sum():
    """With error feedback, quantisation error does not accumulate: the sum
    of dequantised grads tracks the sum of true grads."""
    key = jax.random.PRNGKey(1)
    ef = ErrorFeedback.init({"w": jnp.zeros(64)})
    total_true = np.zeros(64)
    total_sent = np.zeros(64)
    for i in range(50):
        g = {"w": jax.random.normal(jax.random.fold_in(key, i), (64,)) * 0.01}
        q, ef = compress_grads(g, ef)
        sent = decompress_grads(q)
        total_true += np.asarray(g["w"])
        total_sent += np.asarray(sent["w"])
    resid = np.abs(np.asarray(ef.buf["w"])).max()
    assert np.abs(total_true - total_sent).max() <= resid + 1e-5
