import jax
import jax.numpy as jnp
import numpy as np

from repro.launch.hlo_analysis import (analyze_hlo, model_flops,
                                       roofline_terms, HloCosts)
from repro.configs import get_config, SHAPES


def test_scan_trip_count_correction():
    w = jnp.ones((128, 128))

    def f(x):
        y, _ = jax.lax.scan(lambda c, _: (c @ w, None), x, None, length=8)
        return y

    txt = jax.jit(f).lower(jnp.ones((128, 128))).compile().as_text()
    costs = analyze_hlo(txt)
    assert costs.flops == 8 * 2 * 128 ** 3          # exact, trip-corrected
    assert 8 in costs.while_trip_counts.values()


def test_nested_scan_multiplies():
    w = jnp.ones((64, 64))

    def inner(x):
        y, _ = jax.lax.scan(lambda c, _: (c @ w, None), x, None, length=3)
        return y

    def outer(x):
        y, _ = jax.lax.scan(lambda c, _: (inner(c), None), x, None, length=5)
        return y

    txt = jax.jit(outer).lower(jnp.ones((64, 64))).compile().as_text()
    costs = analyze_hlo(txt)
    assert costs.flops == 15 * 2 * 64 ** 3


def test_roofline_terms_dominance():
    c = HloCosts(flops=197e12, bytes=819e9 * 2, collective_bytes=50e9 / 2)
    r = roofline_terms(c, chips=1)
    assert abs(r["compute_s"] - 1.0) < 1e-9
    assert abs(r["memory_s"] - 2.0) < 1e-9
    assert abs(r["collective_s"] - 0.5) < 1e-9
    assert r["dominant"] == "memory"


def test_model_flops_train_vs_decode():
    cfg = get_config("stablelm-1.6b")
    train = model_flops(cfg, SHAPES["train_4k"])
    dec = model_flops(cfg, SHAPES["decode_32k"])
    assert train == 6.0 * cfg.active_param_count() * 4096 * 256
    assert dec == 2.0 * cfg.active_param_count() * 128


def test_moe_uses_active_params():
    cfg = get_config("kimi-k2-1t-a32b")
    assert cfg.active_param_count() < 0.05 * cfg.param_count()
    f = model_flops(cfg, SHAPES["train_4k"])
    assert f == 6.0 * cfg.active_param_count() * 4096 * 256
