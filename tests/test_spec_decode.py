"""Medusa-heads speculative decoding on the serving engine.

The contract: commits only ever come from the real unembedding (row 0 of
the step logits), so the committed token stream is the dense engine's bit
for bit at any ``spec_decode_k``; the draft heads/``draft_fn`` only feed
``verify_step``'s longest-matching-prefix bookkeeping.  Levels:

* model level — ``decode_fn(draft=True)`` appends the k draft-head rows
  without perturbing row 0;
* engine level — token parity vs the vanilla engine under churny admission
  with model heads, a greedy oracle draft (== target: rejects nothing) and
  an adversarial draft (accepts exactly the matching prefix);
* admission level — the ``submit()`` never-servable reach check reads the
  *rounded* pool (page count bumped for N-divisibility and ``--pool-shards``),
  at the boundary, single-device and sharded.
"""

import dataclasses
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.models import api
from repro.serving.engine import Request, ServingEngine

KEY = jax.random.PRNGKey(0)
ROOT = os.path.join(os.path.dirname(__file__), "..")


def _cfg():
    return dataclasses.replace(get_smoke("starcoder2-15b"), dtype="float32")


_PARAMS = {}


def _params(cfg):
    if cfg.name not in _PARAMS:
        _PARAMS[cfg.name] = api.init_params(cfg, KEY)
    return _PARAMS[cfg.name]


#: churny admission: (arrival step, rid, prompt len, max_new) with more
#: requests than slots, so slots turn over mid-run
ARRIVALS = [(0, 0, 5, 4), (0, 1, 7, 3), (2, 2, 3, 5), (4, 3, 6, 4)]


def _drive(eng, arrivals=ARRIVALS):
    """Submit requests at their arrival steps and run to completion."""
    pending = sorted(arrivals)
    reqs, t, i = {}, 0, 0
    for _ in range(300):
        while i < len(pending) and pending[i][0] <= t:
            _, rid, plen, gen = pending[i]
            r = Request(rid, list(range(1, plen + 1)), max_new_tokens=gen)
            eng.submit(r)
            reqs[rid] = r
            i += 1
        live = eng.step()
        t += 1
        if (i == len(pending) and live == 0 and not eng.queue
                and not eng._swapped):
            return reqs
    raise AssertionError("churny workload did not complete")


def _reference_streams(cfg, params):
    eng = ServingEngine(cfg, params, max_slots=2, t_max=16)
    return {rid: list(r.generated) for rid, r in _drive(eng).items()}


# ---------------------------------------------------------------------------
# model level: draft rows ride along, row 0 untouched
# ---------------------------------------------------------------------------

def test_decode_draft_rows_do_not_perturb_row0():
    cfg = dataclasses.replace(_cfg(), spec_heads=2,
                              name="starcoder2-smoke-draft")
    params = api.init_params(cfg, KEY)
    assert params["draft"]["w"].shape[0] == 2
    caches = api.init_cache(cfg, 2, 8)
    tok = jnp.ones((2, 1), jnp.int32)
    dense, _ = api.decode_fn(params, tok, caches, 0, cfg)
    both, _ = api.decode_fn(params, tok, caches, 0, cfg, draft=True)
    assert both.shape == (2, 3, dense.shape[-1])
    np.testing.assert_array_equal(np.asarray(both[:, :1]), np.asarray(dense))


# ---------------------------------------------------------------------------
# engine level: parity + acceptance semantics under churny admission
# ---------------------------------------------------------------------------

def test_spec_model_heads_token_parity_churny():
    """Model draft heads (random init → low acceptance): the committed
    streams still equal the vanilla engine's exactly."""
    cfg = _cfg()
    params = _params(cfg)
    ref = _reference_streams(cfg, params)
    eng = ServingEngine(cfg, params, max_slots=2, t_max=16, spec_decode_k=2)
    got = {rid: list(r.generated) for rid, r in _drive(eng).items()}
    assert got == ref
    assert eng.spec_proposed > 0
    assert eng.spec_accepted + eng.spec_rejected <= eng.spec_proposed


def test_spec_oracle_draft_accepts_everything():
    """A greedy draft that equals the target (it reads the reference
    continuation) never has a proposal rejected."""
    cfg = _cfg()
    params = _params(cfg)
    ref = _reference_streams(cfg, params)

    def oracle(req, committed):
        done = len(req.generated)          # committed token included
        return ref[req.rid][done:done + 2]

    eng = ServingEngine(cfg, params, max_slots=2, t_max=16,
                        spec_decode_k=2, draft_fn=oracle)
    got = {rid: list(r.generated) for rid, r in _drive(eng).items()}
    assert got == ref
    assert eng.spec_accepted > 0
    assert eng.spec_rejected == 0
    assert eng.spec_acceptance > 0


def test_spec_adversarial_draft_accepts_matching_prefix_only():
    """Drafts of [correct, wrong]: the matching prefix (1 token) is
    accepted, the wrong tail rejected — and an always-wrong draft accepts
    nothing.  Token streams never deviate either way."""
    cfg = _cfg()
    params = _params(cfg)
    ref = _reference_streams(cfg, params)
    vocab = cfg.vocab_size

    def half_right(req, committed):
        done = len(req.generated)
        nxt = ref[req.rid][done:done + 1]
        return nxt + [(t + 1) % vocab for t in nxt]     # correct, then wrong

    eng = ServingEngine(cfg, params, max_slots=2, t_max=16,
                        spec_decode_k=2, draft_fn=half_right)
    got = {rid: list(r.generated) for rid, r in _drive(eng).items()}
    assert got == ref
    assert eng.spec_accepted > 0 and eng.spec_rejected > 0
    assert 0 < eng.spec_acceptance < 1

    def always_wrong(req, committed):
        done = len(req.generated)
        return [(t + 1) % vocab for t in ref[req.rid][done:done + 2]]

    eng2 = ServingEngine(cfg, params, max_slots=2, t_max=16,
                         spec_decode_k=2, draft_fn=always_wrong)
    got2 = {rid: list(r.generated) for rid, r in _drive(eng2).items()}
    assert got2 == ref
    assert eng2.spec_accepted == 0
    assert eng2.spec_rejected > 0


# ---------------------------------------------------------------------------
# admission level: submit() reads the rounded pool
# ---------------------------------------------------------------------------

def test_submit_reach_check_sees_rounded_pool():
    """``pool_pages=3`` with page_size 3 on an N=2 fabric rounds to 4
    pages ((3*3) % 2 != 0): a request whose reach needs exactly the rounded
    4 pages must be admitted and served; 5 pages stays never-servable."""
    cfg = _cfg()
    params = _params(cfg)
    eng = ServingEngine(cfg, params, max_slots=1, t_max=15, page_size=3,
                        pool_pages=3)
    assert eng.fabric.n_ports == 2
    assert eng.kv.pool.n_pages == 4            # rounded up from 3
    fits = Request(0, list(range(1, 7)), max_new_tokens=6)   # reach 12 → 4pp
    eng.submit(fits)                           # boundary: must NOT raise
    with pytest.raises(ValueError, match="pages"):
        eng.submit(Request(1, list(range(1, 7)),
                           max_new_tokens=7))  # reach 13 → 5 pages
    eng.run_to_completion()
    assert fits.done and len(fits.generated) == 6


def test_submit_rounded_pool_boundary_under_pool_shards():
    """The same boundary under ``--pool-shards``: rounding must also make
    the page count shard-divisible, and submit() must see that final
    count (subprocess: the XLA device count is frozen at first import)."""
    code = """
import dataclasses
import jax
from repro.configs import get_smoke
from repro.models import api
from repro.serving.engine import Request, ServingEngine

cfg = dataclasses.replace(get_smoke("starcoder2-15b"), dtype="float32")
params = api.init_params(cfg, jax.random.PRNGKey(0))
eng = ServingEngine(cfg, params, max_slots=1, t_max=15, page_size=3,
                    pool_pages=3, pool_shards=2)
assert eng.kv.pool.n_pages == 4, eng.kv.pool.n_pages   # N- and shard-rounded
req = Request(0, list(range(1, 7)), max_new_tokens=6)  # needs all 4 pages
eng.submit(req)                                        # must not raise
try:
    eng.submit(Request(1, list(range(1, 7)), max_new_tokens=7))
except ValueError:
    pass
else:
    raise AssertionError("5-page reach must stay never-servable")
eng.run_to_completion()
assert req.done and len(req.generated) == 6
print("OK")
"""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
    env["PYTHONPATH"] = os.pathsep.join([os.path.join(ROOT, "src"), ROOT])
    res = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=540,
                         cwd=ROOT)
    assert res.returncode == 0, res.stderr[-2000:]
    assert "OK" in res.stdout
