import os
import sys

# Tests see the default (1-device) CPU platform; multi-device tests spawn
# subprocesses with their own XLA_FLAGS (per dry-run instructions, the
# 512-device flag is never set globally).
os.environ.setdefault("REPRO_TEST", "1")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
