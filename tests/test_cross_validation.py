"""Cross-validation: the two independent implementations of the paper's
algorithm — the cycle-accurate pipeline and the log-stage exchange network —
agree with each other (and the oracle) over the whole small design space."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from hypothesis_compat import given, settings, st

from repro.core import medusa_transpose, medusa_transpose_cycle_accurate
from repro.core.burst import MedusaReadSim


@settings(max_examples=20, deadline=None)
@given(st.sampled_from([2, 4, 8, 16]), st.integers(1, 4))
def test_cycle_accurate_equals_exchange_network(n, w):
    x = jax.random.normal(jax.random.PRNGKey(n * 7 + w), (n, n, w))
    a = medusa_transpose_cycle_accurate(x)
    b = medusa_transpose(x, 0, 1)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("n", [2, 4, 8])
def test_burst_sim_agrees_with_unit(n):
    """Feeding the burst simulator one full group reproduces the one-shot
    transposition unit's output on every port."""
    rng = np.random.RandomState(n)
    lines = rng.randn(n, n)
    sim = MedusaReadSim(n, depth=4)
    for p in range(n):
        sim.push_line(p, lines[p])
    sim.run(2 * n)
    # unit view: input banks I[bank=y, addr=p] = word (p, y) → out[p] = line p
    for p in range(n):
        np.testing.assert_allclose(np.asarray(sim.pop_line(p, 0)).ravel(),
                                   lines[p])
