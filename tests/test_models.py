import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_smoke
from repro.data import SyntheticLM
from repro.models import api
from repro.kernels import ops

KEY = jax.random.PRNGKey(0)


@pytest.fixture(autouse=True)
def _no_kernels():
    """Model tests use XLA-native ops (kernels have their own suite)."""
    was = ops.kernels_enabled()
    ops.use_kernels(False)
    yield
    ops.use_kernels(was)


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_arch_smoke_train_step(arch):
    """Reduced same-family config: one forward/loss on CPU, shapes + no NaN."""
    cfg = get_smoke(arch)
    data = SyntheticLM(cfg, batch=2, seq=16)
    batch = {k: jnp.asarray(v) for k, v in data.batch_at(0).items()}
    params = api.init_params(cfg, KEY)
    loss = api.loss_fn(params, batch, cfg)
    assert loss.shape == ()
    assert np.isfinite(float(loss))


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_arch_smoke_serve(arch):
    cfg = get_smoke(arch)
    data = SyntheticLM(cfg, batch=2, seq=12)
    batch = {k: jnp.asarray(v) for k, v in data.batch_at(0).items()}
    batch.pop("targets")
    t_max = 16 + (cfg.n_patches or 0)
    logits, caches = api.prefill_fn(params := api.init_params(cfg, KEY),
                                    batch, cfg, t_max)
    assert np.all(np.isfinite(np.asarray(logits, np.float32)))
    pos = batch["tokens"].shape[1] + (cfg.n_patches or 0)
    l2, caches = api.decode_fn(params, batch["tokens"][:, :1], caches, pos, cfg)
    assert l2.shape[0] == 2 and l2.shape[1] == 1
    assert np.all(np.isfinite(np.asarray(l2, np.float32)))
