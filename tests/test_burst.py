import numpy as np
import pytest

from repro.core.burst import MedusaReadSim


def test_single_line_constant_latency():
    n = 8
    sim = MedusaReadSim(n, depth=4)
    rng = np.random.RandomState(0)
    line = rng.randn(n)
    sim.push_line(3, line)
    sim.run(n)
    assert sim.completion_latency(3, 0) == n  # §III-E constant N cycles
    np.testing.assert_allclose(np.asarray(sim.pop_line(3, 0)).ravel(), line)


def test_fifo_order_per_port():
    n = 4
    sim = MedusaReadSim(n, depth=8)
    rng = np.random.RandomState(1)
    lines = [rng.randn(n) for _ in range(3)]
    for l in lines:
        sim.push_line(2, l)
        sim.step()
    sim.run(3 * n)
    for i, l in enumerate(lines):
        np.testing.assert_allclose(np.asarray(sim.pop_line(2, i)).ravel(), l)


def test_interference_freedom():
    """Port A's completion time is identical with and without port B traffic
    (paper §III-F: no inter-port interference)."""
    n = 4
    rng = np.random.RandomState(2)
    line_a = rng.randn(n)
    # run 1: port 1 alone
    sim1 = MedusaReadSim(n, depth=8)
    sim1.push_line(1, line_a)
    sim1.run(2 * n)
    t_alone = sim1.completion_latency(1, 0)
    # run 2: ports 0,2,3 saturated with bursts
    sim2 = MedusaReadSim(n, depth=8)
    for p in (0, 2, 3):
        for _ in range(4):
            sim2.push_line(p, rng.randn(n))
    sim2.push_line(1, line_a)
    sim2.run(8 * n)
    t_busy = sim2.completion_latency(1, 0)
    assert t_alone == t_busy == n
    np.testing.assert_allclose(np.asarray(sim2.pop_line(1, 0)).ravel(), line_a)


def test_mid_stream_join():
    """A port can join while others are mid-transposition (§III-F)."""
    n = 4
    rng = np.random.RandomState(3)
    sim = MedusaReadSim(n, depth=8)
    sim.push_line(0, rng.randn(n))
    sim.step(); sim.step()              # port 0 mid-line
    late = rng.randn(n)
    sim.push_line(3, late)              # joins at current phase
    sim.run(3 * n)
    assert sim.completion_latency(3, 0) == n
    np.testing.assert_allclose(np.asarray(sim.pop_line(3, 0)).ravel(), late)


def test_overflow_backpressure():
    n, d = 4, 2
    sim = MedusaReadSim(n, depth=d)
    line = np.zeros(n)
    sim.push_line(0, line)
    sim.push_line(0, line)
    with pytest.raises(RuntimeError):
        sim.push_line(0, line)          # depth exceeded without draining
