import jax
import jax.numpy as jnp
import numpy as np
import pytest

from hypothesis_compat import given, settings, st

from repro.core import (barrel_rotate, index_twist, baseline_mux_count,
                        medusa_mux_count, mux_reduction, rotation_depth)


@pytest.mark.parametrize("n", [2, 4, 8, 16, 32, 64])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16, jnp.int32])
def test_barrel_rotate_equals_roll(n, dtype):
    x = jnp.arange(n * 3, dtype=dtype).reshape(n, 3)
    for c in (0, 1, n - 1, n, 2 * n + 3):
        np.testing.assert_array_equal(
            np.asarray(barrel_rotate(x, c)), np.asarray(jnp.roll(x, -c, 0)))


def test_barrel_rotate_other_axis():
    x = jax.random.normal(jax.random.PRNGKey(0), (3, 8, 2))
    np.testing.assert_allclose(np.asarray(barrel_rotate(x, 5, axis=1)),
                               np.asarray(jnp.roll(x, -5, 1)))


def test_barrel_rotate_rejects_non_pow2():
    with pytest.raises(ValueError):
        barrel_rotate(jnp.zeros((6, 2)), 1)


@settings(max_examples=30, deadline=None)
@given(st.integers(0, 63), st.integers(0, 63))
def test_rotation_composes(a, b):
    x = jnp.arange(16.0).reshape(16, 1)
    once = barrel_rotate(barrel_rotate(x, a), b)
    combined = barrel_rotate(x, a + b)
    np.testing.assert_allclose(np.asarray(once), np.asarray(combined))


def test_index_twist():
    n = 8
    x = jnp.arange(n * n).reshape(n, n)
    t = index_twist(x, 0, 1, -1)
    ref = jnp.stack([jnp.roll(x[b], -b) for b in range(n)])
    np.testing.assert_array_equal(np.asarray(t), np.asarray(ref))
    # inverse twist restores
    back = index_twist(t, 0, 1, +1)
    np.testing.assert_array_equal(np.asarray(back), np.asarray(x))


def test_mux_counts_match_paper():
    # §II-B / §III-D at the paper design point: 512-bit line, 32 ports
    assert baseline_mux_count(512, 32) == 512 * 31
    assert medusa_mux_count(512, 32) == 512 * 5
    assert abs(mux_reduction(512, 32) - 6.2) < 0.01
    assert rotation_depth(32) == 5
