"""Pool-sharded fabric lowering (``FabricConfig.pool_shards``).

Three levels, mirroring the fused-gather acceptance bar:

* plan level (host-only) — :func:`repro.fabric.shard_plan` buckets a sparse
  burst's frame list by (requesting shard, owning shard); a numpy
  simulation of the two-hop lowering (local fetch → exchange → placement)
  must reproduce ``take`` exactly, sentinels and duplicates included;
* allocator level (host-only) — :class:`repro.fabric.PagePool` stripes
  allocation round-robin over the shard blocks and ``check()`` enforces the
  per-shard conservation invariant through churn;
* burst + engine level — in a subprocess per forced host device count
  (1/2/4/8; the XLA device count is frozen at first jax import): the
  sharded read/write bursts, and a full churny-arrival engine run, are
  bit-identical to their single-device fused-gather equivalents.
"""

import os
import subprocess
import sys

import numpy as np
import pytest

from repro.fabric import PagePool, shard_plan
from repro.fabric.scheduler import FRAME_SENTINEL as SENTINEL

ROOT = os.path.join(os.path.dirname(__file__), "..")


def _run(code, devices=8, timeout=540):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.pathsep.join([os.path.join(ROOT, "src"), ROOT])
    return subprocess.run([sys.executable, "-c", code], env=env,
                          capture_output=True, text=True, timeout=timeout,
                          cwd=ROOT)


# ---------------------------------------------------------------------------
# plan level: shard_plan is an exact decomposition of take
# ---------------------------------------------------------------------------

def _simulate_plan(plan, pool, frames, reps):
    """Numpy re-enactment of the two-hop lowering on a scalar-per-line pool
    ``[reps, frames]``: each owner fetches its local rows, the exchange
    transposes the (owner, requestor) blocks, each requestor places what it
    received.  Returns the reassembled ``[k_tot]`` request stream."""
    s, cap = plan.n_shards, plan.cap
    f_loc, k_loc = frames // s, plan.k_tot // s
    local = [pool[:, o * f_loc:(o + 1) * f_loc].reshape(-1)
             for o in range(s)]                      # rep-major local rows
    out = np.zeros(plan.k_tot, pool.dtype)
    for o in range(s):
        for r in range(s):
            rows = plan.fetch[o, r]
            sent = np.where(rows < reps * f_loc, local[o][rows % (reps * f_loc)],
                            0)                       # sentinel rows fetch 0
            dst = plan.place[r, o]
            keep = dst < k_loc                       # sentinel placements drop
            out[r * k_loc + dst[keep]] = sent[keep]
    return out


@pytest.mark.parametrize("n_shards", (1, 2, 4))
@pytest.mark.parametrize("reps", (1, 3))
def test_shard_plan_reproduces_take(n_shards, reps):
    """Fetch → exchange → place == ``take(pool, tiled_indices)`` for churny
    index lists: shuffled, duplicated, sentinel-padded."""
    frames, n = 32, 4
    rng = np.random.RandomState(7 * n_shards + reps)
    k = 48 // reps * reps
    while (reps * k) % (n_shards * n):
        k += 1
    idx = rng.randint(0, frames, size=k).astype(np.int64)
    idx[rng.permutation(k)[:5]] = SENTINEL           # padding requests
    idx[1] = idx[0]                                  # duplicate frame
    plan = shard_plan(idx, frames, n_shards, n, reps=reps)
    pool = rng.randn(reps, frames)
    got = _simulate_plan(plan, pool, frames, reps)
    tiled = np.tile(idx, reps)
    rep_of = np.arange(reps * k) // k
    want = np.where(tiled < frames,
                    pool[rep_of, np.minimum(tiled, frames - 1)], 0)
    np.testing.assert_array_equal(got, want)
    assert plan.cross_frames + plan.local_frames == int(
        (tiled < frames).sum())


def test_shard_plan_cap_rounding_and_validation():
    idx = np.arange(16, dtype=np.int64)
    plan = shard_plan(idx, 16, 2, 4, cap_bucket=6)
    assert plan.cap % 4 == 0 and plan.cap % 6 == 0   # N and bucket rounding
    assert plan.fetch.shape == plan.place.shape == (2, 2, plan.cap)
    with pytest.raises(ValueError, match="shard blocks"):
        shard_plan(np.arange(10, dtype=np.int64), 16, 2, 4)   # 10 % (2*4)
    with pytest.raises(ValueError, match="equal shard blocks"):
        shard_plan(idx, 15, 2, 4)                    # frames % shards
    with pytest.raises(ValueError, match="n_shards"):
        shard_plan(idx, 16, 0, 4)


def test_shard_plan_striped_traffic_is_mostly_local():
    """Round-robin-striped frames (the PagePool allocation order) leave
    exactly 1/S of the requests on their owning shard: ``cross_frames`` is
    ``(S-1)/S`` of the live traffic — the bench's locality split."""
    s, n, frames = 4, 4, 64
    f_loc = frames // s
    k = 32
    idx = ((np.arange(k) % s) * f_loc + np.arange(k) // s).astype(np.int64)
    plan = shard_plan(idx, frames, s, n)
    assert plan.local_frames == k // s
    assert plan.cross_frames == k - k // s


# ---------------------------------------------------------------------------
# allocator level: round-robin striping + per-shard conservation
# ---------------------------------------------------------------------------

def test_pool_striping_balances_shards():
    pool = PagePool(page_size=4, n_pages=16, pages_per_slot=4, n_slots=4,
                    n_shards=4)
    assert pool.free_pages_by_shard == (4, 4, 4, 4)
    pool.ensure(0, 2)                                # 2 logical pages
    pool.ensure(1, 2)                                # 2 logical pages
    assert pool.free_pages_by_shard == (3, 3, 3, 3)
    # each allocated page landed in a distinct shard block, lowest-first
    mapped = sorted(p for row in pool.table for p in row if p >= 0)
    assert [pool.shard_of(p) for p in mapped] == [0, 1, 2, 3]
    pool.check()


def test_pool_per_shard_conservation_through_churn():
    pool = PagePool(page_size=2, n_pages=8, pages_per_slot=4, n_slots=3,
                    n_shards=2)
    pool.ensure(0, 3)                                # 3 logical pages
    pool.check()
    pool.ensure(1, 2)                                # 2 logical pages
    pool.check()
    assert sum(pool.free_pages_by_shard) == pool.free_pages == 3
    pool.release(0)
    pool.check()
    assert pool.free_pages == 6
    # released pages went home: every block's stack + mapped rows still
    # partition exactly that block (check() would raise otherwise)
    pool.ensure(2, 4)                                # re-use released pages
    pool.check()


def test_pool_shard_count_must_divide_pages():
    with pytest.raises(ValueError, match="shard"):
        PagePool(page_size=2, n_pages=10, pages_per_slot=2, n_slots=2,
                 n_shards=4)


def test_pool_unsharded_is_seed_allocator():
    """``n_shards=1`` must behave exactly like the seed: one block, pages
    allocated lowest-first."""
    pool = PagePool(page_size=2, n_pages=6, pages_per_slot=3, n_slots=2)
    pool.ensure(0, 3)
    mapped = [p for p in pool.table[0] if p >= 0]
    assert mapped == [0, 1, 2]
    assert pool.n_shards == 1 and pool.free_pages_by_shard == (3,)
    pool.check()


# ---------------------------------------------------------------------------
# burst + engine level: bit-parity per forced device count (subprocess)
# ---------------------------------------------------------------------------

_BURST_CODE = """
import numpy as np, jax, jax.numpy as jnp, dataclasses
from repro.fabric import Fabric, shard_plan
from repro.fabric.sharded import make_pool_mesh
from repro.fabric.scheduler import FRAME_SENTINEL
from repro.kernels import ops
shards, n, w, frames, collective = {shards}, 4, 6, 32, "{collective}"
ops.use_kernels({kernels})
rng = np.random.RandomState(3)
idx = rng.randint(0, frames, size=24).astype(np.int32)
idx[5] = idx[4]                                   # duplicate frame
idx = np.concatenate([idx, np.full(8, FRAME_SENTINEL, np.int32)])
pool = jax.random.normal(jax.random.PRNGKey(0), (frames, n, w), jnp.float32)
upd = jax.random.normal(jax.random.PRNGKey(1),
                        (idx.shape[0] // n, n, n, w), jnp.float32)
ref_fab = Fabric.make(n, "medusa")
ref_read = ref_fab.read_burst(pool, indices=jnp.asarray(idx))
ref_pool = ref_fab.write_burst(upd, indices=jnp.asarray(idx), into=pool)
fab = dataclasses.replace(
    Fabric.make(n, "medusa", pool_shards=shards, collective=collective),
    mesh=make_pool_mesh(shards))
plan = shard_plan(idx, frames, shards, n)
fetch, place = plan.operands()
got_read = fab.read_burst_sharded(pool[None], fetch, place, plan.k_tot)
got_pool = fab.write_burst_sharded(upd, fetch, place, pool[None])
np.testing.assert_array_equal(np.asarray(got_read), np.asarray(ref_read))
np.testing.assert_array_equal(np.asarray(got_pool[0]), np.asarray(ref_pool))
print("OK")
"""


@pytest.mark.parametrize("shards,collective,kernels",
                         [(2, "all_to_all", False),
                          (4, "ring", False),
                          (8, "all_to_all", True)])
def test_sharded_bursts_match_single_device(shards, collective, kernels):
    """``read_burst_sharded``/``write_burst_sharded`` == the single-device
    sparse bursts, bit for bit, across shard counts × collectives × the
    fused-kernel toggle (duplicates and sentinel rows included)."""
    r = _run(_BURST_CODE.format(shards=shards, collective=collective,
                                kernels=kernels), devices=shards)
    assert "OK" in r.stdout, r.stderr[-3000:]


_ENGINE_CODE = """
import dataclasses, numpy as np
from repro.kernels import ops
ops.use_kernels(False)
from repro.configs import get_smoke
from tests.test_paged_pool import _drive
cfg = dataclasses.replace(get_smoke("starcoder2-15b"), dtype="float32")
arrivals = [(0, 5, 4), (0, 9, 3), (2, 2, 6), (4, 11, 2), (6, 3, 3)]
gen_r, logs_r, lives_r, _ = _drive(cfg, arrivals, paged_pool=True)
gen_s, logs_s, lives_s, eng = _drive(cfg, arrivals, paged_pool=True,
                                     pool_shards={shards})
assert gen_r == gen_s, (gen_r, gen_s)
assert lives_r == lives_s
for i, (a, b) in enumerate(zip(logs_r, logs_s)):
    np.testing.assert_array_equal(a, b, err_msg=f"step {{i}}")
fs = eng.fabric_stats
if {shards} > 1:
    assert eng.pool_shards == {shards}
    assert eng.kv.pool.n_shards == {shards}
    assert fs.collective_calls > 0
    assert fs.words_cross_shard > 0
else:
    assert fs.collective_calls == 0 and fs.words_cross_shard == 0
print("OK")
"""


@pytest.mark.parametrize("shards", (1, 2, 4, 8))
def test_engine_sharded_bit_identical_churny(shards):
    """The full churny-arrival engine matrix (slot reuse, staggered
    arrivals, mixed prompt lengths — the ``test_fused_gather`` workload) is
    bit-identical between the pool-sharded engine at 1/2/4/8 forced devices
    and the single-device fused-gather engine; ``_drive`` runs the
    per-shard ``PagePool.check()`` invariant every step."""
    r = _run(_ENGINE_CODE.format(shards=shards), devices=max(shards, 1))
    assert "OK" in r.stdout, (r.stdout[-500:], r.stderr[-3000:])
