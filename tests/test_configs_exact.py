"""Guard the assigned architecture numbers against drift — exact values."""
import pytest

from repro.configs import get_config, ARCHS, SHAPES


EXACT = {
    "starcoder2-15b": dict(n_layers=40, d_model=6144, n_heads=48,
                           n_kv_heads=4, d_ff=24576, vocab_size=49152),
    "stablelm-1.6b": dict(n_layers=24, d_model=2048, n_heads=32,
                          n_kv_heads=32, d_ff=5632, vocab_size=100352),
    "gemma3-12b": dict(n_layers=48, d_model=3840, n_heads=16, n_kv_heads=8,
                       d_ff=15360, vocab_size=262144),
    "gemma3-4b": dict(n_layers=34, d_model=2560, n_heads=8, n_kv_heads=4,
                      d_ff=10240, vocab_size=262144),
    "internvl2-1b": dict(n_layers=24, d_model=896, n_heads=14, n_kv_heads=2,
                         d_ff=4864, vocab_size=151655),
    "mamba2-780m": dict(n_layers=48, d_model=1536, d_ff=0,
                        vocab_size=50280),
    "recurrentgemma-2b": dict(n_layers=26, d_model=2560, n_heads=10,
                              n_kv_heads=1, d_ff=7680, vocab_size=256000),
    "granite-moe-3b-a800m": dict(n_layers=32, d_model=1536, n_heads=24,
                                 n_kv_heads=8, vocab_size=49155),
    "kimi-k2-1t-a32b": dict(n_layers=61, d_model=7168, n_heads=64,
                            n_kv_heads=8, vocab_size=163840),
    "whisper-medium": dict(n_layers=24, d_model=1024, n_heads=16,
                           n_kv_heads=16, d_ff=4096, vocab_size=51865,
                           encoder_layers=24, encoder_seq=1500),
}


@pytest.mark.parametrize("arch", sorted(EXACT))
def test_exact_assigned_numbers(arch):
    cfg = get_config(arch)
    for field, want in EXACT[arch].items():
        assert getattr(cfg, field) == want, (arch, field)


def test_moe_configs():
    g = get_config("granite-moe-3b-a800m").moe
    assert (g.n_experts, g.top_k, g.expert_d_ff) == (40, 8, 512)
    k = get_config("kimi-k2-1t-a32b").moe
    assert (k.n_experts, k.top_k, k.expert_d_ff) == (384, 8, 2048)


def test_ssm_config():
    m = get_config("mamba2-780m").ssm
    assert m.d_state == 128
    assert get_config("mamba2-780m").block_pattern == "M"


def test_shapes_exact():
    assert (SHAPES["train_4k"].seq_len, SHAPES["train_4k"].global_batch) == (4096, 256)
    assert (SHAPES["prefill_32k"].seq_len, SHAPES["prefill_32k"].global_batch) == (32768, 32)
    assert (SHAPES["decode_32k"].seq_len, SHAPES["decode_32k"].global_batch) == (32768, 128)
    assert (SHAPES["long_500k"].seq_len, SHAPES["long_500k"].global_batch) == (524288, 1)


def test_param_counts_near_nameplate():
    # name-plate sanity: within tolerance of the advertised sizes
    targets = {"starcoder2-15b": (15e9, 16.5e9), "gemma3-12b": (11e9, 13e9),
               "gemma3-4b": (3.5e9, 4.5e9), "mamba2-780m": (0.7e9, 0.85e9),
               "kimi-k2-1t-a32b": (0.95e12, 1.1e12),
               "whisper-medium": (0.7e9, 0.8e9)}
    for arch, (lo, hi) in targets.items():
        n = get_config(arch).param_count()
        assert lo <= n <= hi, (arch, n)
    k = get_config("kimi-k2-1t-a32b")
    assert 28e9 <= k.active_param_count() <= 34e9       # "A32B"
    g = get_config("granite-moe-3b-a800m")
    assert 0.7e9 <= g.active_param_count() <= 1.0e9     # "A800M"


def test_pattern_tiling():
    for arch in ARCHS:
        cfg = get_config(arch)
        types = cfg.layer_types()
        assert len(types) == cfg.n_layers
        assert set(types) <= {"A", "L", "R", "M"}
