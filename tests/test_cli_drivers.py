"""Smoke the production CLI drivers end to end (subprocesses, CPU 1x1 mesh)."""
import os
import subprocess
import sys

import pytest

ROOT = os.path.join(os.path.dirname(__file__), "..")
ENV = dict(os.environ, PYTHONPATH=os.path.join(ROOT, "src"))


@pytest.mark.slow
def test_train_cli_with_failure_injection(tmp_path):
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.train", "--arch",
         "stablelm-1.6b", "--smoke", "--steps", "12", "--batch", "4",
         "--seq", "32", "--ckpt-dir", str(tmp_path), "--ckpt-every", "4",
         "--fail-at", "6", "--log-every", "4"],
        env=ENV, capture_output=True, text=True, timeout=560, cwd=ROOT)
    assert "done at step 12" in r.stdout, (r.stdout[-1200:], r.stderr[-800:])
    assert "restarts=1" in r.stdout


@pytest.mark.slow
def test_serve_cli(tmp_path):
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.serve", "--arch", "gemma3-4b",
         "--smoke", "--batch", "2", "--prompt-len", "16", "--gen-len", "8"],
        env=ENV, capture_output=True, text=True, timeout=560, cwd=ROOT)
    assert "tok/s" in r.stdout, (r.stdout[-1200:], r.stderr[-800:])


@pytest.mark.slow
def test_serve_cli_engine_burst_scheduled(tmp_path):
    """Engine path with the packed burst-scheduled decode + weight stream."""
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.serve", "--arch", "gemma3-4b",
         "--smoke", "--batch", "2", "--prompt-len", "12", "--gen-len", "6",
         "--engine", "--pack", "packed", "--serve-fsdp"],
        env=ENV, capture_output=True, text=True, timeout=560, cwd=ROOT)
    assert "tok/s" in r.stdout, (r.stdout[-1200:], r.stderr[-800:])
    assert "network calls" in r.stdout, r.stdout[-1200:]


@pytest.mark.slow
def test_loadgen_cli(tmp_path):
    """Traffic harness CLI: seeded trace, aging, bounded queue, trace
    round-trip, and the BENCH_serving.json trajectory append."""
    bench = str(tmp_path / "bench.json")
    trace = str(tmp_path / "trace.json")
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.loadgen", "--smoke",
         "--requests", "8", "--rate", "0.8", "--aging", "6",
         "--max-queue", "6", "--deadline-frac", "0.3",
         "--trace-out", trace, "--bench-out", bench],
        env=ENV, capture_output=True, text=True, timeout=560, cwd=ROOT)
    assert "aggregate" in r.stdout, (r.stdout[-1200:], r.stderr[-800:])
    assert "degradation census" in r.stdout
    assert "STARVED" not in r.stdout
    import json
    with open(bench) as f:
        runs = json.load(f)["runs"]
    assert len(runs) == 1 and runs[0]["mode"] == "drive"
    assert "aggregate" in runs[0]["cells"]
    assert os.path.exists(trace)


@pytest.mark.slow
def test_loadgen_cli_soak_replicas(tmp_path):
    """Fault-soak lane shape: oversubscribed pool + seeded injector over a
    2-replica fleet, token-exact convergence asserted in-process."""
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.loadgen", "--smoke",
         "--requests", "8", "--rate", "0.8", "--replicas", "2",
         "--pool-pages", "10", "--preempt", "swap", "--soak",
         "--soak-p-fail", "0.05", "--soak-p-exhaust", "0.1",
         "--no-bench"],
        env=ENV, capture_output=True, text=True, timeout=560, cwd=ROOT)
    assert "fault soak: token-exact" in r.stdout, (r.stdout[-1200:],
                                                   r.stderr[-800:])
    assert "STARVED" not in r.stdout
