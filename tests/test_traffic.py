"""Production-shaped traffic harness: seeded load generation, lifecycle
metrics, anti-starvation aging, SLO-aware load shedding, backpressure,
the replica router, and fault soak.

The acceptance bar:

* the generator is bit-replayable (same ``TrafficConfig`` → identical
  trace; JSON round-trip exact) and its knobs (arrival modes, class mix,
  deadline mix) actually shape the trace;
* **starvation regression**: under sustained high-priority churn a
  low-priority request starves with ``aging=0`` (strict ``_rank`` order —
  the PR 7 residual) but with aging on it retires within the provable
  wait bound AND its tokens are bit-identical to an uncontended reference
  (aging reorders, it never corrupts);
* **load shedding is provable**: a deadline that cannot be met under any
  schedule is rejected at ``submit()`` with a counted reason, a meetable
  one is never rejected, and a queued request whose deadline becomes
  unmeetable while it waits is shed *before* the deadline passes — so no
  deadlined request is ever silently served late;
* the SLO census counts at **every** exit path (late retire →
  ``slo_missed_served``; shed with a deadline → ``slo_missed_shed``;
  never-servable raise included), and ``slo_misses`` is their sum;
* ``run_to_completion``'s stall error names per-class depths, pool
  headroom and swap occupancy;
* the fault-soak harness converges token-exact with zero page leaks.
"""

import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.kernels import ops
from repro.models import api
from repro.runtime.fault_tolerance import FaultInjector
from repro.serving import (MetricsRecorder, ReplicaRouter, Request,
                           ServingEngine, TraceRecord, TrafficConfig, drive,
                           fault_soak, generate_trace, load_trace,
                           save_trace, trace_t_max)

KEY = jax.random.PRNGKey(11)


def _cfg():
    return dataclasses.replace(get_smoke("starcoder2-15b"), dtype="float32")


_PARAMS = {}


def _params(cfg):
    if cfg.name not in _PARAMS:
        _PARAMS[cfg.name] = api.init_params(cfg, KEY)
    return _PARAMS[cfg.name]


def _prompt(rid: int, length: int, vocab: int) -> np.ndarray:
    return np.asarray(jax.random.randint(jax.random.fold_in(KEY, 2000 + rid),
                                         (length,), 0, vocab), np.int32)


def _engine(cfg, **kw):
    kw.setdefault("max_slots", 1)
    kw.setdefault("t_max", 16)
    kw.setdefault("page_size", 4)
    kw.setdefault("check_pool", True)
    return ServingEngine(cfg, _params(cfg), **kw)


# ---------------------------------------------------------------------------
# trace generation: replayability + knobs (no model, fast)
# ---------------------------------------------------------------------------

def _trace_eq(a, b):
    assert len(a) == len(b)
    for x, y in zip(a, b):
        assert (x.rid, x.arrival_step, x.max_new_tokens, x.priority,
                x.deadline) == (y.rid, y.arrival_step, y.max_new_tokens,
                                y.priority, y.deadline)
        np.testing.assert_array_equal(x.prompt, y.prompt)


def test_trace_deterministic_and_seed_sensitive():
    cfg = TrafficConfig(seed=3, n_requests=40, deadline_frac=0.5)
    _trace_eq(generate_trace(cfg), generate_trace(cfg))
    other = generate_trace(dataclasses.replace(cfg, seed=4))
    same = generate_trace(cfg)
    assert any((x.arrival_step, len(x.prompt)) != (y.arrival_step,
                                                   len(y.prompt))
               for x, y in zip(same, other))


def test_trace_shape_knobs():
    # heavy-tailed lengths stay clipped; class mix favours class 0
    cfg = TrafficConfig(seed=0, n_requests=200, classes=3,
                        prompt_min=2, prompt_max=12, gen_min=2, gen_max=9,
                        deadline_frac=0.3)
    tr = generate_trace(cfg)
    assert all(2 <= len(t.prompt) <= 12 for t in tr)
    assert all(2 <= t.max_new_tokens <= 9 for t in tr)
    counts = [sum(t.priority == c for t in tr) for c in range(3)]
    assert counts[0] > counts[1] > 0          # geometric default weights
    n_dead = sum(t.deadline is not None for t in tr)
    assert 0 < n_dead < len(tr)
    for t in tr:
        if t.deadline is not None:            # slack 3.0 over service floor
            assert t.deadline == t.arrival_step + 3 * (t.max_new_tokens + 2)
    assert trace_t_max(tr) == max(len(t.prompt) + t.max_new_tokens
                                  for t in tr) + 1
    # diurnal bursts compress arrivals vs flat poisson at the same rate
    flat = generate_trace(TrafficConfig(seed=1, n_requests=100, rate=0.5))
    bursty = generate_trace(TrafficConfig(
        seed=1, n_requests=100, rate=0.5, arrival="diurnal",
        burst_prob=0.2, burst_mult=6.0))
    assert max(t.arrival_step for t in bursty) != \
        max(t.arrival_step for t in flat)


def test_trace_config_validation():
    with pytest.raises(ValueError, match="arrival"):
        TrafficConfig(arrival="uniform").validate()
    with pytest.raises(ValueError, match="class"):
        TrafficConfig(classes=0).validate()
    with pytest.raises(ValueError, match="class_weights"):
        TrafficConfig(classes=3, class_weights=[1.0]).validate()
    with pytest.raises(ValueError, match="deadline_frac"):
        TrafficConfig(deadline_frac=1.5).validate()


def test_trace_json_roundtrip(tmp_path):
    tr = generate_trace(TrafficConfig(seed=9, n_requests=25,
                                      deadline_frac=0.4))
    path = str(tmp_path / "trace.json")
    save_trace(path, tr)
    _trace_eq(tr, load_trace(path))


# ---------------------------------------------------------------------------
# SLO-aware load shedding (satellite: provable, counted, every exit path)
# ---------------------------------------------------------------------------

def test_unmeetable_deadline_shed_at_submit():
    ops.use_kernels(False)
    cfg = _cfg()
    eng = _engine(cfg)
    # fresh request: prompt 4 + gen 6 in t_max 16 → earliest retire at
    # step 0 + min(6-2, 16-4-2) = 4; deadline 3 is provably unmeetable
    req = Request(0, _prompt(0, 4, cfg.vocab_size), max_new_tokens=6,
                  deadline=3)
    assert eng.submit(req) == "shed"
    assert req.done and req.shed_reason == "deadline" and not req.generated
    fs = eng.fabric_stats
    assert (fs.requests_shed, fs.shed_deadline, fs.slo_missed_shed) == \
        (1, 1, 1)
    assert fs.slo_missed_served == 0 and eng.slo_misses == 1
    # the tightest meetable deadline (== the exact floor) is NEVER shed —
    # and the engine then actually meets it
    ok = Request(1, _prompt(0, 4, cfg.vocab_size), max_new_tokens=6,
                 deadline=4)
    assert eng.submit(ok) == "queued"
    eng.run_to_completion()
    assert ok.done and ok.shed_reason is None and len(ok.generated) == 6
    assert eng.fabric_stats.slo_missed_served == 0


def test_preempt_off_tightens_admission_floor():
    ops.use_kernels(False)
    cfg = _cfg()
    eng = _engine(cfg, preempt="off")
    long = Request(0, _prompt(0, 4, cfg.vocab_size), max_new_tokens=8)
    eng.submit(long)
    eng.step()                        # long is live: retires at step >= 7
    # with preemption off the slot frees only at retirement, so a fresh
    # request needing the slot can prove deadline 9 hopeless NOW (earliest
    # admit step 8, own floor +2) even though 9 > its immediate floor
    late = Request(1, _prompt(1, 4, cfg.vocab_size), max_new_tokens=6,
                   deadline=9)
    assert eng.submit(late) == "shed" and late.shed_reason == "deadline"
    fits = Request(2, _prompt(2, 4, cfg.vocab_size), max_new_tokens=6,
                   deadline=30)
    assert eng.submit(fits) == "queued"
    eng.run_to_completion()
    assert fits.done and len(fits.generated) == 6


def test_queued_deadline_shed_before_it_passes():
    ops.use_kernels(False)
    cfg = _cfg()
    # swap mode: the submit-time floor does NOT tighten (preemption can
    # free pages any step), so the deadlined request queues — then the
    # admission-time recheck sheds it the moment waiting made the deadline
    # provably unmeetable, NOT silently after it passed
    eng = _engine(cfg, preempt="swap")
    hog = Request(0, _prompt(0, 4, cfg.vocab_size), max_new_tokens=8,
                  priority=1)
    eng.submit(hog)
    eng.step()
    req = Request(1, _prompt(1, 4, cfg.vocab_size), max_new_tokens=4,
                  deadline=eng.step_count + 2)   # floor: +2 → meetable now
    assert eng.submit(req) == "queued"           # lower class: waits
    eng.run_to_completion()
    assert hog.done and len(hog.generated) == 8
    assert req.done and req.shed_reason == "deadline"
    assert eng.fabric_stats.slo_missed_shed == 1
    assert eng.fabric_stats.slo_missed_served == 0


def test_served_late_counts_slo_missed_served():
    ops.use_kernels(False)
    cfg = _cfg()
    eng = _engine(cfg)
    req = Request(0, _prompt(0, 4, cfg.vocab_size), max_new_tokens=4)
    eng.submit(req)
    eng.step()
    # the deadline tightens AFTER admission (external cancellation shape —
    # admission-time shedding can no longer help): the late retirement
    # must land in slo_missed_served, not vanish
    req.deadline = 0
    eng.run_to_completion()
    assert req.done and len(req.generated) == 4
    fs = eng.fabric_stats
    assert fs.slo_missed_served == 1 and fs.slo_missed_shed == 0
    assert eng.slo_misses == 1


def test_never_servable_raise_still_counts():
    ops.use_kernels(False)
    cfg = _cfg()
    eng = _engine(cfg, t_max=8)
    with pytest.raises(ValueError, match="cannot decode"):
        eng.submit(Request(0, _prompt(0, 8, cfg.vocab_size),
                           max_new_tokens=2, deadline=50))
    fs = eng.fabric_stats
    assert fs.requests_shed == 1 and fs.slo_missed_shed == 1


def test_shed_serves_survivors_bit_identical():
    ops.use_kernels(False)
    cfg = _cfg()
    eng = _engine(cfg, max_slots=2)
    a = Request(0, _prompt(0, 5, cfg.vocab_size), max_new_tokens=4)
    b = Request(1, _prompt(1, 4, cfg.vocab_size), max_new_tokens=4,
                deadline=0)                      # born unmeetable
    c = Request(2, _prompt(2, 6, cfg.vocab_size), max_new_tokens=4)
    assert [eng.submit(r) for r in (a, b, c)] == ["queued", "shed", "queued"]
    eng.run_to_completion()
    ref = _engine(cfg, max_slots=2)
    ra = Request(0, _prompt(0, 5, cfg.vocab_size), max_new_tokens=4)
    rc = Request(2, _prompt(2, 6, cfg.vocab_size), max_new_tokens=4)
    ref.submit(ra), ref.submit(rc)
    ref.run_to_completion()
    assert a.generated == ra.generated and c.generated == rc.generated
    assert b.generated == []


# ---------------------------------------------------------------------------
# backpressure (bounded submit queue)
# ---------------------------------------------------------------------------

def test_max_queue_backpressure():
    ops.use_kernels(False)
    cfg = _cfg()
    eng = _engine(cfg, max_queue=1)
    kept = Request(0, _prompt(0, 4, cfg.vocab_size), max_new_tokens=3)
    spill = Request(1, _prompt(1, 4, cfg.vocab_size), max_new_tokens=3,
                    deadline=40)
    assert eng.submit(kept) == "queued"
    assert eng.submit(spill) == "shed"
    assert spill.shed_reason == "queue_full"
    fs = eng.fabric_stats
    assert fs.shed_queue_full == 1 and fs.requests_shed == 1
    assert fs.slo_missed_shed == 1      # the spilled one carried a deadline
    eng.run_to_completion()
    assert kept.done and len(kept.generated) == 3
    # the queue drained: submits flow again
    late = Request(2, _prompt(2, 4, cfg.vocab_size), max_new_tokens=3)
    assert eng.submit(late) == "queued"
    eng.run_to_completion()
    assert late.done and len(late.generated) == 3


def test_engine_rejects_bad_admission_knobs():
    ops.use_kernels(False)
    cfg = _cfg()
    with pytest.raises(ValueError, match="aging"):
        _engine(cfg, aging=-1)
    with pytest.raises(ValueError, match="max_queue"):
        _engine(cfg, max_queue=-1)


# ---------------------------------------------------------------------------
# anti-starvation aging (satellite: the PR 7 fairness residual)
# ---------------------------------------------------------------------------

def _churn(cfg, aging, steps):
    """Sustained high-priority churn against one low-priority request:
    keep >= 2 class-1 requests pending at all times, so with strict
    priority order the class-0 request can never reach the single slot."""
    eng = _engine(cfg, preempt="off", aging=aging)
    low = Request(0, _prompt(0, 4, cfg.vocab_size), max_new_tokens=4)
    eng.submit(low)
    highs, nxt = [], 1
    for _ in range(steps):
        while sum(not h.done for h in highs) < 2:
            h = Request(nxt, _prompt(nxt, 4, cfg.vocab_size),
                        max_new_tokens=4, priority=1)
            eng.submit(h)
            highs.append(h)
            nxt += 1
        eng.step()
        if low.done:
            break
    return eng, low


def test_starvation_without_aging_fixed_by_aging():
    ops.use_kernels(False)
    cfg = _cfg()
    AGING, STEPS = 4, 40
    # aging off: strict _rank order — the low request is still queued
    # after 40 steps of churn (the starvation the harness measures)
    eng0, low0 = _churn(cfg, aging=0, steps=STEPS)
    assert not low0.done and low0 in eng0.queue
    assert eng0.fabric_stats.aging_promotions == 0
    # aging on: after AGING * (gap+1) waited steps the low request's
    # effective class passes the churn's, it admits, and it retires within
    # the provable bound: promotion wait + one live residency + own service
    eng1, low1 = _churn(cfg, aging=AGING, steps=STEPS)
    assert low1.done and low1.shed_reason is None
    assert eng1.step_count <= 2 * AGING + 4 + 4 + 2
    assert eng1.fabric_stats.aging_promotions >= 1
    # fairness never costs correctness: tokens match an uncontended run
    ref = _engine(cfg)
    r = Request(0, _prompt(0, 4, cfg.vocab_size), max_new_tokens=4)
    ref.submit(r)
    ref.run_to_completion()
    assert low1.generated == r.generated


def test_aged_request_not_preempted_back():
    ops.use_kernels(False)
    cfg = _cfg()
    # swap mode: an aged-up class-0 request that reached the slot must not
    # be evicted by a fresh class-1 arrival — its effective class only
    # grows, so preemption eligibility uses the same aged rank
    eng = _engine(cfg, preempt="swap", aging=2)
    low = Request(0, _prompt(0, 4, cfg.vocab_size), max_new_tokens=6)
    eng.submit(low)
    eng.step()                         # low is live, aging from step 0
    for _ in range(4):
        eng.step()                     # low's effective class reaches 2
    fresh = Request(1, _prompt(1, 4, cfg.vocab_size), max_new_tokens=4,
                    priority=1)
    eng.submit(fresh)
    eng.run_to_completion()
    assert eng.fabric_stats.preemptions == 0
    assert low.done and len(low.generated) == 6
    assert fresh.done and len(fresh.generated) == 4


# ---------------------------------------------------------------------------
# stall census (satellite: diagnosable run_to_completion error)
# ---------------------------------------------------------------------------

def test_stall_error_names_census():
    ops.use_kernels(False)
    cfg = _cfg()
    eng = _engine(cfg, preempt="swap")
    eng.submit(Request(0, _prompt(0, 4, cfg.vocab_size), max_new_tokens=6))
    eng.submit(Request(1, _prompt(1, 4, cfg.vocab_size), max_new_tokens=6,
                       priority=1))
    with pytest.raises(RuntimeError) as ei:
        eng.run_to_completion(max_steps=2)
    msg = str(ei.value)
    assert "class0: 1" in msg and "class1: 1" in msg
    assert "pool headroom" in msg and "swap space" in msg
    eng.run_to_completion()            # and the workload itself was fine


# ---------------------------------------------------------------------------
# recorder + drive + router (tentpole integration)
# ---------------------------------------------------------------------------

_TCFG = TrafficConfig(seed=2, n_requests=6, rate=0.8, prompt_mean=5.0,
                      prompt_max=8, gen_mean=4.0, gen_max=6, classes=2,
                      vocab=64)


def test_drive_records_lifecycle():
    ops.use_kernels(False)
    cfg = _cfg()
    trace = generate_trace(dataclasses.replace(_TCFG, vocab=cfg.vocab_size))
    eng = _engine(cfg, max_slots=2, t_max=trace_t_max(trace), aging=4)
    rec = drive(eng, trace, max_steps=500)
    rep = rec.report()
    agg = rep["aggregate"]
    assert agg["n"] == 6 and agg["served"] == 6 and agg["shed"] == 0
    assert agg["tokens"] == sum(t.max_new_tokens for t in trace)
    assert agg["goodput"] == 1.0
    # stamps are coherent: submit <= admit = first token (prefill commits
    # the first token in the admit step), wait/ttft percentiles finite
    assert agg["ttft_p50"] is not None and agg["ttft_p50"] >= 0
    assert agg["wait_p99"] >= agg["wait_p50"] >= 0
    assert rec.starved() == []
    assert set(rec.requests) == {t.rid for t in trace}
    assert "aggregate" in rec.format_table()
    per_class = [k for k in rep if k.startswith("class")]
    assert len(per_class) == len({t.priority for t in trace})


def test_replica_router_balances_and_aggregates():
    ops.use_kernels(False)
    cfg = _cfg()
    trace = generate_trace(dataclasses.replace(_TCFG, vocab=cfg.vocab_size))
    router = ReplicaRouter([
        _engine(cfg, t_max=trace_t_max(trace)) for _ in range(2)])
    rec = drive(router, trace, max_steps=500)
    assert rec.report()["aggregate"]["served"] == 6
    # least-loaded routing actually spread the trace over both replicas
    per_engine = [e.fabric_stats.prefill_bursts for e in router.engines]
    assert all(n > 0 for n in per_engine)
    stats = router.stats()
    assert stats["prefill_bursts"] == sum(per_engine)
    assert router.drained and router.pending_census()


def test_fault_soak_converges():
    ops.use_kernels(False)
    cfg = _cfg()
    trace = generate_trace(dataclasses.replace(
        _TCFG, deadline_frac=0.3, vocab=cfg.vocab_size))
    t_max = trace_t_max(trace)

    def make_engine(fault_injector=None):
        return _engine(cfg, max_slots=2, t_max=t_max, pool_pages=8,
                       preempt="swap", fault_injector=fault_injector)

    inj = FaultInjector.seeded(7, 100, p_fail=0.05, p_exhaust=0.1,
                               n_corrupt=1)
    ref_rec, soak_rec, target = fault_soak(make_engine, trace,
                                           max_steps=500, injector=inj)
    fs = target.fabric_stats
    assert fs.faults_recovered + fs.bursts_retried + \
        len(inj.exhaust_fired) > 0          # the soak actually hit faults
    assert soak_rec.report()["aggregate"]["served"] >= 1
