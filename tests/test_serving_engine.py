"""Per-slot decode + continuous-batching engine correctness."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.fabric import BurstScheduler, Fabric, SchedulerStats
from repro.kernels import ops
from repro.models import api, lm
from repro.serving import ServingEngine, Request

KEY = jax.random.PRNGKey(3)


def _fp32(cfg):
    return dataclasses.replace(cfg, dtype="float32")


def test_vector_positions_match_scalar():
    """A batch decoding at two different depths must match each sequence
    decoded independently (the per-slot position path)."""
    ops.use_kernels(False)
    cfg = _fp32(get_smoke("gemma3-12b"))     # hybrid: both cache kinds
    params = api.init_params(cfg, KEY)
    t_max = 24
    toks = jax.random.randint(KEY, (2, 12), 0, cfg.vocab_size)

    # independent reference: each row prefilled/decoded alone at its depth
    depths = [6, 10]
    ref_logits = []
    caches_rows = []
    for r, d in enumerate(depths):
        _, c = api.prefill_fn(params, {"tokens": toks[r:r+1, :d]}, cfg, t_max)
        caches_rows.append(c)
        l, _ = api.decode_fn(params, toks[r:r+1, d:d+1], c, d, cfg)
        ref_logits.append(np.asarray(l[0, 0]))

    # batched: splice both rows into one cache, decode with vector positions
    batch_cache = api.init_cache(cfg, 2, t_max)

    def splice(bc, rc, slot):
        def one(b, r):
            axis = 1 if b.ndim >= 4 and b.shape[1] == 2 else 0
            idx = [slice(None)] * b.ndim
            idx[axis] = slice(slot, slot + 1)
            return b.at[tuple(idx)].set(r)
        return jax.tree.map(one, bc, rc)

    for r in range(2):
        batch_cache = splice(batch_cache, caches_rows[r], r)
    tok = jnp.stack([toks[0, depths[0]], toks[1, depths[1]]])[:, None]
    pos = jnp.asarray(depths, jnp.int32)
    logits, _ = api.decode_fn(params, tok, batch_cache, pos, cfg)
    for r in range(2):
        np.testing.assert_allclose(np.asarray(logits[r, 0]), ref_logits[r],
                                   atol=2e-4)


def test_engine_matches_sequential_generation():
    ops.use_kernels(False)
    cfg = _fp32(get_smoke("starcoder2-15b"))
    params = api.init_params(cfg, KEY)
    prompts = [np.asarray(jax.random.randint(jax.random.fold_in(KEY, i),
                                             (6 + 2 * i,), 0, cfg.vocab_size),
                          np.int32) for i in range(3)]
    # reference: one-at-a-time greedy generation
    refs = []
    for pr in prompts:
        out = api.greedy_generate(params, jnp.asarray(pr)[None], cfg,
                                  steps=5, t_max=32)
        first_logits, _ = api.prefill_fn(params, {"tokens": jnp.asarray(pr)[None]},
                                         cfg, 32)
        first = int(np.argmax(np.asarray(first_logits[0, -1])))
        refs.append([first] + np.asarray(out[0]).tolist())

    # engine with 2 slots over 3 requests (forces slot reuse/backfill)
    eng = ServingEngine(cfg, params, max_slots=2, t_max=32)
    reqs = [Request(i, pr, max_new_tokens=6) for i, pr in enumerate(prompts)]
    for r in reqs:
        eng.submit(r)
    eng.run_to_completion(max_steps=64)
    for r, ref in zip(reqs, refs):
        assert r.done
        assert r.generated == ref, (r.rid, r.generated, ref)


# ---------------------------------------------------------------------------
# burst-scheduled decode (the scheduler's first production consumer)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("pack", ("packed", "pad"))
@pytest.mark.parametrize("vector_pos", (False, True))
def test_scheduled_decode_bit_identical(pack, vector_pos):
    """decode_fn with a BurstScheduler (KV banking hoisted into one read +
    one write burst, attention in port-major space) is bit-identical to the
    per-layer path — logits and the returned line-major caches — for scalar
    and per-slot positions, both burst layouts."""
    ops.use_kernels(False)
    cfg = _fp32(get_smoke("starcoder2-15b"))
    params = api.init_params(cfg, KEY)
    toks = jax.random.randint(KEY, (2, 9), 0, cfg.vocab_size)
    _, caches = api.prefill_fn(params, {"tokens": toks[:, :8]}, cfg, 12)
    pos = jnp.asarray([8, 8], jnp.int32) if vector_pos else jnp.int32(8)

    ref_logits, ref_caches = api.decode_fn(params, toks[:, 8:9], caches,
                                           pos, cfg)
    fab = Fabric(dataclasses.replace(cfg.resolved_fabric, pack=pack))
    stats = SchedulerStats()
    sched = BurstScheduler(fab, stats=stats)
    logits, new_caches = api.decode_fn(params, toks[:, 8:9], caches, pos,
                                       cfg, sched=sched)
    assert stats.flushes == 2                      # read burst + write burst
    assert stats.network_calls == 2                # one per direction (f32)
    np.testing.assert_array_equal(np.asarray(logits), np.asarray(ref_logits))
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a), np.asarray(b)), ref_caches, new_caches)


@pytest.mark.parametrize("why", ("geometry", "fused"))
def test_scheduled_decode_falls_back(why):
    """Decode must fall back to the per-layer path, silently and
    value-identically, when the fabric is off the port-per-KV-head geometry
    (can't bank the leaves) or is ``fused`` (banking would materialize
    exactly the port-major copies the fused impl elides)."""
    ops.use_kernels(False)
    from repro.configs.base import FabricConfig
    base = _fp32(get_smoke("starcoder2-15b"))
    if why == "geometry":
        cfg = dataclasses.replace(base, fabric=FabricConfig(
            n_ports=base.n_kv_heads * base.resolved_head_dim // 8,
            lane_width=8, impl="oracle"))
    else:
        cfg = dataclasses.replace(base, kv_layout="fused")
    params = api.init_params(cfg, KEY)
    toks = jax.random.randint(KEY, (1, 6), 0, cfg.vocab_size)
    _, caches = api.prefill_fn(params, {"tokens": toks[:, :5]}, cfg, 8)
    stats = SchedulerStats()
    sched = BurstScheduler(Fabric(cfg.resolved_fabric), stats=stats)
    logits, _ = api.decode_fn(params, toks[:, 5:6], caches, jnp.int32(5),
                              cfg, sched=sched)
    assert stats.flushes == 0                      # scheduler never engaged
    ref, _ = api.decode_fn(params, toks[:, 5:6], caches, jnp.int32(5), cfg)
    np.testing.assert_array_equal(np.asarray(logits), np.asarray(ref))


def test_engine_decode_traffic_census():
    """The engine's traced decode step runs exactly 1 read + 1 write network
    invocation per dtype per step, serving every full-attention leaf; the
    single admission wave adds exactly one eager prefill write burst."""
    ops.use_kernels(False)
    cfg = _fp32(get_smoke("starcoder2-15b"))
    params = api.init_params(cfg, KEY)
    eng = ServingEngine(cfg, params, max_slots=2, t_max=16)
    eng.submit(Request(0, np.asarray([3, 1, 4], np.int32), max_new_tokens=2))
    eng.run_to_completion(max_steps=8)
    # 1 prefill write burst (eager, per admission wave) + 1 read + 1 write
    # per traced decode step
    assert eng.fabric_stats.prefill_bursts == 1
    assert eng.fabric_stats.flushes == 3
    assert eng.fabric_stats.network_calls == 3     # all f32
    assert eng.fabric_stats.words_padded == 0      # packed default
    assert eng.fabric_stats.words_moved > 0


def test_engine_dense_mode_traffic_census_unchanged():
    """With the pool off (the A/B baseline) the census is the PR 2 shape:
    admission splices, the traced step is 1 read + 1 write burst."""
    ops.use_kernels(False)
    cfg = _fp32(get_smoke("starcoder2-15b"))
    params = api.init_params(cfg, KEY)
    eng = ServingEngine(cfg, params, max_slots=2, t_max=16, paged_pool=False)
    eng.submit(Request(0, np.asarray([3, 1, 4], np.int32), max_new_tokens=2))
    eng.run_to_completion(max_steps=8)
    assert eng.fabric_stats.prefill_bursts == 0
    assert eng.fabric_stats.flushes == 2
    assert eng.fabric_stats.network_calls == 2


def test_engine_serve_fsdp_streams_weights_bit_identically():
    """serve_fsdp routes the per-step weight re-gather through the same read
    burst as the KV banking (weight_stream ports) — same greedy tokens, same
    network-call count, more streams served."""
    ops.use_kernels(False)
    cfg = _fp32(get_smoke("starcoder2-15b"))
    params = api.init_params(cfg, KEY)
    prompts = [np.asarray([5, 2, 7, 1], np.int32),
               np.asarray([9, 9, 3], np.int32)]

    def serve(c):
        eng = ServingEngine(c, params, max_slots=2, t_max=16)
        reqs = [Request(i, p, max_new_tokens=4) for i, p in enumerate(prompts)]
        for r in reqs:
            eng.submit(r)
        eng.run_to_completion(max_steps=16)
        return [r.generated for r in reqs], eng.fabric_stats

    gen, stats = serve(cfg)
    gen_fsdp, stats_fsdp = serve(dataclasses.replace(cfg, serve_fsdp=True))
    assert gen == gen_fsdp
    # 1 prefill write burst per admission wave + 1 read + 1 write per step
    assert stats_fsdp.network_calls == stats.network_calls == 3
    assert stats_fsdp.streams_served > stats.streams_served
