"""Per-slot decode + continuous-batching engine correctness."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke
from repro.kernels import ops
from repro.models import api, lm
from repro.serving import ServingEngine, Request

KEY = jax.random.PRNGKey(3)


def _fp32(cfg):
    return dataclasses.replace(cfg, dtype="float32")


def test_vector_positions_match_scalar():
    """A batch decoding at two different depths must match each sequence
    decoded independently (the per-slot position path)."""
    ops.use_kernels(False)
    cfg = _fp32(get_smoke("gemma3-12b"))     # hybrid: both cache kinds
    params = api.init_params(cfg, KEY)
    t_max = 24
    toks = jax.random.randint(KEY, (2, 12), 0, cfg.vocab_size)

    # independent reference: each row prefilled/decoded alone at its depth
    depths = [6, 10]
    ref_logits = []
    caches_rows = []
    for r, d in enumerate(depths):
        _, c = api.prefill_fn(params, {"tokens": toks[r:r+1, :d]}, cfg, t_max)
        caches_rows.append(c)
        l, _ = api.decode_fn(params, toks[r:r+1, d:d+1], c, d, cfg)
        ref_logits.append(np.asarray(l[0, 0]))

    # batched: splice both rows into one cache, decode with vector positions
    batch_cache = api.init_cache(cfg, 2, t_max)

    def splice(bc, rc, slot):
        def one(b, r):
            axis = 1 if b.ndim >= 4 and b.shape[1] == 2 else 0
            idx = [slice(None)] * b.ndim
            idx[axis] = slice(slot, slot + 1)
            return b.at[tuple(idx)].set(r)
        return jax.tree.map(one, bc, rc)

    for r in range(2):
        batch_cache = splice(batch_cache, caches_rows[r], r)
    tok = jnp.stack([toks[0, depths[0]], toks[1, depths[1]]])[:, None]
    pos = jnp.asarray(depths, jnp.int32)
    logits, _ = api.decode_fn(params, tok, batch_cache, pos, cfg)
    for r in range(2):
        np.testing.assert_allclose(np.asarray(logits[r, 0]), ref_logits[r],
                                   atol=2e-4)


def test_engine_matches_sequential_generation():
    ops.use_kernels(False)
    cfg = _fp32(get_smoke("starcoder2-15b"))
    params = api.init_params(cfg, KEY)
    prompts = [np.asarray(jax.random.randint(jax.random.fold_in(KEY, i),
                                             (6 + 2 * i,), 0, cfg.vocab_size),
                          np.int32) for i in range(3)]
    # reference: one-at-a-time greedy generation
    refs = []
    for pr in prompts:
        out = api.greedy_generate(params, jnp.asarray(pr)[None], cfg,
                                  steps=5, t_max=32)
        first_logits, _ = api.prefill_fn(params, {"tokens": jnp.asarray(pr)[None]},
                                         cfg, 32)
        first = int(np.argmax(np.asarray(first_logits[0, -1])))
        refs.append([first] + np.asarray(out[0]).tolist())

    # engine with 2 slots over 3 requests (forces slot reuse/backfill)
    eng = ServingEngine(cfg, params, max_slots=2, t_max=32)
    reqs = [Request(i, pr, max_new_tokens=6) for i, pr in enumerate(prompts)]
    for r in reqs:
        eng.submit(r)
    eng.run_to_completion(max_steps=64)
    for r, ref in zip(reqs, refs):
        assert r.done
        assert r.generated == ref, (r.rid, r.generated, ref)
