"""Shared physical KV page pool: churn parity, free-list conservation,
burst-scheduled prefill admission, and the dense-splice accounting fix.

The acceptance bar for the paged pool:

* the pool engine is **bit-identical** to the dense engine on live slots —
  logits per step and greedy tokens — under arbitrary admit/extend/retire
  churn (the gather reconstructs exactly the frames the dense layout holds;
  everything else is masked);
* no physical page is ever leaked or double-mapped (``PagePool.check`` runs
  after every engine step), and retirement truly reclaims;
* admission through the ``prefill/*`` write burst is bit-identical to the
  per-layer splice across pack × word_fold × kernel combos (the write
  network is an exact round trip), including the off-geometry fallback and
  waves admitted mid-decode;
* ``tokens_moved_dense`` counts the splice the seed engine would actually
  pay: the full unknown region on a slot's first fill, but only
  ``max(span, prior occupant's extent)`` on reuse.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.configs.base import FabricConfig
from repro.fabric import Fabric, PagePool, PagedKVCache
from repro.kernels import ops
from repro.models import api, lm
from repro.serving import Request, ServingEngine

from tests.hypothesis_compat import given, settings, st

KEY = jax.random.PRNGKey(7)


def _cfg():
    return dataclasses.replace(get_smoke("starcoder2-15b"), dtype="float32")


_PARAMS = {}


def _params(cfg):
    if cfg.name not in _PARAMS:
        _PARAMS[cfg.name] = api.init_params(cfg, KEY)
    return _PARAMS[cfg.name]


def _prompt(rid: int, length: int, vocab: int) -> np.ndarray:
    return np.asarray(jax.random.randint(jax.random.fold_in(KEY, 1000 + rid),
                                         (length,), 0, vocab), np.int32)


# ---------------------------------------------------------------------------
# churn driver: scripted arrivals, per-step logits + invariants
# ---------------------------------------------------------------------------

def _drive(cfg, arrivals, *, paged_pool, max_slots=2, t_max=24, page_size=4,
           max_steps=64, **eng_kw):
    """Run an engine over scripted ``(arrival_step, prompt_len, max_new)``
    requests; returns (generated per request, per-step live-slot logits,
    per-step live sets, engine).  Pool invariants are checked every step."""
    # check_pool: the conservation invariant runs inside every step (the
    # --check-pool debug flag, default on in tests)
    eng = ServingEngine(cfg, _params(cfg), max_slots=max_slots, t_max=t_max,
                        page_size=page_size, paged_pool=paged_pool,
                        check_pool=True, **eng_kw)
    pending = sorted(enumerate(arrivals), key=lambda a: a[1][0])
    reqs = []
    logs, lives = [], []
    for step in range(max_steps):
        while pending and pending[0][1][0] <= step:
            rid, (_, plen, mnew) = pending.pop(0)
            r = Request(rid, _prompt(rid, plen, cfg.vocab_size),
                        max_new_tokens=mnew)
            reqs.append(r)
            eng.submit(r)
        # _admit here so the decode-time live set is observable; the admit
        # inside step() is then a no-op (no free slot with a waiting queue)
        eng._admit()
        live = [s for s in range(max_slots) if eng.active[s] is not None]
        if not live and not eng.queue and not pending:
            break
        eng.step()
        if live:
            logs.append(np.asarray(eng.last_logits))
            lives.append(live)
        if eng.kv.paged:
            eng.kv.pool.check()
            assert 0.0 <= eng.kv.occupancy <= 1.0
            for s in live:
                if eng.active[s] is None:
                    continue               # retired during this step: freed
                # every position written so far (plus the next write) is
                # backed by a mapped page
                assert (eng.kv.pool.mapped(s)
                        >= eng.kv.table.pages_for(int(eng.pos[s])))
    assert not pending and not eng.queue, "driver ran out of steps"
    reqs.sort(key=lambda r: r.rid)
    return [r.generated for r in reqs], logs, lives, eng


def _assert_bit_identical_runs(cfg, arrivals, **kw):
    gen_d, logs_d, lives_d, _ = _drive(cfg, arrivals, paged_pool=False, **kw)
    gen_p, logs_p, lives_p, eng = _drive(cfg, arrivals, paged_pool=True, **kw)
    assert gen_d == gen_p, (gen_d, gen_p)
    assert lives_d == lives_p
    for i, (a, b, lv) in enumerate(zip(logs_d, logs_p, lives_d)):
        for s in lv:
            np.testing.assert_array_equal(
                a[s], b[s], err_msg=f"step {i} slot {s} logits diverged")
    return eng


def test_churn_bit_identical_to_dense_engine():
    """Slot reuse, staggered arrivals, mixed prompt lengths: the pool engine
    matches the dense engine bit-for-bit on every live slot's logits."""
    ops.use_kernels(False)
    cfg = _cfg()
    arrivals = [(0, 5, 4), (0, 9, 3), (2, 2, 6), (4, 11, 2), (6, 3, 3)]
    eng = _assert_bit_identical_runs(cfg, arrivals)
    # all retired: every page reclaimed, nothing leaked
    assert eng.kv.pool.pages_in_use == 0
    assert eng.kv.pool.pages_allocated == eng.kv.pool.pages_reclaimed > 0
    assert eng.kv.occupancy == 0.0


def test_churn_bit_identical_hybrid_ring_caches():
    """Hybrid pattern (gemma3: sliding-window ring caches stay dense
    per-slot, only the full-attention layers pool): same bit-parity bar."""
    ops.use_kernels(False)
    cfg = dataclasses.replace(get_smoke("gemma3-12b"), dtype="float32")
    arrivals = [(0, 4, 4), (1, 7, 4), (3, 10, 3)]
    _assert_bit_identical_runs(cfg, arrivals, t_max=32)


def test_pool_occupancy_below_dense_reservation():
    """Mixed short/long workload: the pool's peak physical footprint stays
    under the dense layout's reservation (the HBM-sharing claim)."""
    ops.use_kernels(False)
    cfg = _cfg()
    eng = ServingEngine(cfg, _params(cfg), max_slots=2, t_max=32, page_size=4)
    eng.submit(Request(0, _prompt(0, 3, cfg.vocab_size), max_new_tokens=4))
    eng.submit(Request(1, _prompt(1, 20, cfg.vocab_size), max_new_tokens=4))
    peak = 0
    for _ in range(16):
        if eng.step() == 0 and not eng.queue:
            break
        peak = max(peak, eng.kv.pool.pages_in_use)
    assert 0 < peak < eng.kv.dense_reserved_pages
    assert eng.fabric_stats.prefill_bursts >= 1    # per admission wave


def test_pool_admission_blocks_until_reclaim():
    """A pool smaller than the dense reservation admits what fits and holds
    the rest at the head of the queue until retirement reclaims pages —
    decode never hits pool exhaustion."""
    ops.use_kernels(False)
    cfg = _cfg()
    # 3 pages of 8: one slot's worth of a 17-token sequence at a time
    eng = ServingEngine(cfg, _params(cfg), max_slots=2, t_max=16, page_size=8,
                        pool_pages=3)
    reqs = [Request(i, _prompt(10 + i, 9, cfg.vocab_size), max_new_tokens=3)
            for i in range(2)]
    for r in reqs:
        eng.submit(r)
    eng.step()
    # only one admitted: 9+1 tokens need 2 pages, the second request's 2
    # don't fit in the 1 left
    assert sum(r is not None for r in eng.active) == 1
    eng.run_to_completion(max_steps=32)
    assert all(r.done for r in reqs)
    eng.kv.pool.check()
    assert eng.kv.pool.pages_in_use == 0


def test_pool_exhaustion_raises():
    pool = PagePool(page_size=4, n_pages=2, pages_per_slot=4, n_slots=2)
    pool.ensure(0, 2)
    with pytest.raises(RuntimeError, match="exhausted"):
        pool.ensure(1, 1)
    pool.release(0)
    pool.ensure(1, 2)                              # reclaimed pages reusable
    pool.check()


# ---------------------------------------------------------------------------
# hypothesis churn sweep
# ---------------------------------------------------------------------------

_ARRIVALS = st.lists(
    st.tuples(st.integers(0, 5), st.integers(1, 11), st.integers(1, 5)),
    min_size=1, max_size=5)


@settings(max_examples=3, deadline=None)
@given(arrivals=_ARRIVALS, page_size=st.sampled_from([1, 3, 4, 8]))
def test_property_churn_parity(arrivals, page_size):
    """Random admit/extend/retire churn × page sizes (including pages that
    don't divide the cache depth): bit-identical logits per step, no page
    leaked or double-mapped, occupancy invariants (checked in the driver)."""
    ops.use_kernels(False)
    cfg = _cfg()
    eng = _assert_bit_identical_runs(cfg, arrivals, page_size=page_size)
    assert eng.kv.pool.pages_in_use == 0           # all retired → reclaimed


@pytest.mark.slow
@settings(max_examples=20, deadline=None)
@given(arrivals=_ARRIVALS, page_size=st.sampled_from([1, 2, 3, 4, 5, 8, 24]),
       undersized=st.booleans(), fused=st.booleans())
def test_property_churn_parity_sweep(arrivals, page_size, undersized, fused):
    """Long churn sweep (nightly lane): wider page-size space plus
    undersized pools, × the fused-gather contract on/off (churny page
    tables — holes from retirement, reused pages, ``-1`` rows — through
    both the sparse-extent bursts and the gather-after fallback).  An
    undersized pool gates admission, which reorders the schedule relative
    to the dense engine — so it is driven solo for conservation/occupancy
    invariants (sized for one worst-case request, so progress is
    guaranteed), while full pools keep the bit-parity bar."""
    ops.use_kernels(False)
    cfg = _cfg()
    if not undersized:
        eng = _assert_bit_identical_runs(cfg, arrivals, page_size=page_size,
                                         fused_gather=fused)
    else:
        # one worst-case request's reach (len 11 + 5 new, t_max 24)
        pool_pages = -(-16 // page_size)
        _, _, _, eng = _drive(cfg, arrivals, paged_pool=True,
                              page_size=page_size, pool_pages=pool_pages,
                              max_steps=256, fused_gather=fused)
    assert eng.kv.pool.pages_in_use == 0
    eng.kv.pool.check()
    assert (eng.fabric_stats.gather_fused_bursts > 0) == fused


# ---------------------------------------------------------------------------
# burst-scheduled prefill admission parity
# ---------------------------------------------------------------------------

def _fresh_kv(cfg, fabric, max_slots, t_alloc, ps):
    pages_per_slot = -(-t_alloc // ps)
    pool_pages = max_slots * pages_per_slot
    while (pool_pages * ps) % fabric.n_ports:
        pool_pages += 1
    caches = api.init_cache(cfg, max_slots, t_alloc, pool_pages=pool_pages,
                            page_size=ps)
    return PagedKVCache(caches, max_slots, t_alloc, ps,
                        pool_pages=pool_pages,
                        paged_entries=lm.paged_entries(cfg), fabric=fabric)


def _req_caches(cfg, lengths, t_alloc):
    out = []
    for i, ln in enumerate(lengths):
        prompt = jnp.asarray(_prompt(50 + i, ln, cfg.vocab_size))[None, :]
        _, rc = api.prefill_fn(_params(cfg), {"tokens": prompt}, cfg, t_alloc)
        out.append(rc)
    return out


@pytest.mark.parametrize("pack", ("packed", "pad"))
@pytest.mark.parametrize("fold", (1, 2, "auto"))
@pytest.mark.parametrize("kernels", (False, True))
def test_prefill_burst_matches_splice(pack, fold, kernels):
    """One write-burst admission wave installs bit-identically to the
    per-layer splice, for every burst layout × machine-word fold × fused-
    kernel combination (the write network is an exact round trip)."""
    cfg = _cfg()
    t_alloc, ps = 16, 4
    lengths = (5, 9)
    rcs = _req_caches(cfg, lengths, t_alloc)
    entries = [(s, rc, ln) for s, (rc, ln) in enumerate(zip(rcs, lengths))]
    fab = Fabric(dataclasses.replace(cfg.resolved_fabric, pack=pack,
                                     word_fold=fold))
    prev = ops.kernels_enabled()
    ops.use_kernels(kernels)
    try:
        kv_burst = _fresh_kv(cfg, fab, 2, t_alloc, ps)
        kv_burst.admit_wave(entries, burst=True)
        kv_splice = _fresh_kv(cfg, fab, 2, t_alloc, ps)
        kv_splice.admit_wave(entries, burst=False)
    finally:
        ops.use_kernels(prev)
    assert kv_burst.prefill_bursts == 1 and kv_burst.prefill_splices == 0
    assert kv_splice.prefill_bursts == 0 and kv_splice.prefill_splices == 2
    assert np.array_equal(kv_burst.pool.table, kv_splice.pool.table)
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a), np.asarray(b)), kv_burst.caches, kv_splice.caches)


def test_prefill_burst_off_geometry_fallback():
    """Slots whose page extents don't divide N splice; slots that do ride
    the burst — one mixed wave exercises both, bit-identically to the all-
    splice install.  (1-layer config: reps=1, so a 1-page span of 3 frames
    is odd against N=2.)"""
    ops.use_kernels(False)
    cfg = dataclasses.replace(_cfg(), n_layers=1, name="starcoder2-smoke-1l")
    t_alloc, ps = 12, 3
    lengths = (2, 4)               # spans 3 (odd → splice) and 6 (burst)
    rcs = _req_caches(cfg, lengths, t_alloc)
    entries = [(s, rc, ln) for s, (rc, ln) in enumerate(zip(rcs, lengths))]
    fab = Fabric(cfg.resolved_fabric)
    kv_auto = _fresh_kv(cfg, fab, 2, t_alloc, ps)
    kv_auto.admit_wave(entries)                    # burst=None: per-slot auto
    assert kv_auto.prefill_bursts == 1 and kv_auto.prefill_splices == 1
    kv_splice = _fresh_kv(cfg, fab, 2, t_alloc, ps)
    kv_splice.admit_wave(entries, burst=False)
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a), np.asarray(b)), kv_auto.caches, kv_splice.caches)


def test_prefill_burst_fused_fabric_splices():
    """The fused fabric never banks, so admission always splices."""
    ops.use_kernels(False)
    cfg = dataclasses.replace(_cfg(), kv_layout="fused")
    rcs = _req_caches(cfg, (5,), 16)
    kv = _fresh_kv(cfg, Fabric(cfg.resolved_fabric), 2, 16, 4)
    kv.admit_wave([(0, rcs[0], 5)])
    assert kv.prefill_bursts == 0 and kv.prefill_splices == 1


def test_mixed_admit_and_decode_step_parity():
    """An admission wave landing while other slots decode (the production
    pattern): burst-admitted engine and splice-admitted engine stay
    bit-identical through the mixed step and beyond."""
    ops.use_kernels(False)
    cfg = _cfg()
    arrivals = [(0, 6, 5), (2, 9, 3), (3, 2, 4)]   # admissions mid-decode
    gen_b, logs_b, lives_b, eng_b = _drive(cfg, arrivals, paged_pool=True,
                                           prefill_burst=True)
    gen_s, logs_s, lives_s, eng_s = _drive(cfg, arrivals, paged_pool=True,
                                           prefill_burst=False)
    assert gen_b == gen_s and lives_b == lives_s
    for a, b, lv in zip(logs_b, logs_s, lives_b):
        for s in lv:
            np.testing.assert_array_equal(a[s], b[s])
    assert eng_b.fabric_stats.prefill_bursts >= 2  # ≥ 1 per admission wave
    assert eng_s.fabric_stats.prefill_bursts == 0


def test_resolve_fabric_rejects_page_deeper_than_cache():
    """Build-time validation: an explicit fabric whose page is deeper than
    the decode cache is a config error, caught before lowering."""
    from repro.configs.base import SHAPES
    from repro.launch.steps import resolve_fabric
    cfg = dataclasses.replace(_cfg(), fabric=FabricConfig(
        n_ports=2, lane_width=16, page_size=40_000))
    with pytest.raises(ValueError, match="page_size"):
        resolve_fabric(cfg, SHAPES["decode_32k"])
    ok = dataclasses.replace(_cfg(), fabric=FabricConfig(
        n_ports=2, lane_width=16, page_size=64))
    assert resolve_fabric(ok, SHAPES["decode_32k"]).page_size == 64


# ---------------------------------------------------------------------------
# refill accounting regression (the dense-splice counterfactual)
# ---------------------------------------------------------------------------

def test_refill_dense_counterfactual_accounting():
    """``tokens_moved_dense`` counts the seed engine's actual splice: the
    whole unknown region on a slot's first fill, ``max(span, prior
    occupant's extent)`` on reuse — not ``t_max`` every time."""
    cfg = _cfg()
    caches = api.init_cache(cfg, 2, 32)
    kv = PagedKVCache(caches, max_slots=2, t_max=32, page_size=8)
    req = api.init_cache(cfg, 1, 32)
    kv.refill(0, req, n_tokens=9)                  # 2 pages of 8
    assert kv.tokens_moved == 16
    assert kv.tokens_moved_dense == 32             # first fill: full region
    kv.extend(0, 20)                               # occupant wrote 20 frames
    kv.free(0)
    kv.refill(0, req, n_tokens=5)                  # reuse: span 8, prior 20
    assert kv.tokens_moved == 16 + 8
    assert kv.tokens_moved_dense == 32 + 20        # max(8, 20), not 32
    kv.refill(1, req, n_tokens=5)                  # fresh slot: full region
    assert kv.tokens_moved_dense == 32 + 20 + 32
    kv.free(1)
    kv.refill(1, req, n_tokens=30)                 # reuse, prompt > prior
    assert kv.tokens_moved_dense == 32 + 20 + 32 + 32   # max(span=32, 8)
