"""Hypothesis property tests on system invariants."""
import jax
import jax.numpy as jnp
import numpy as np

from hypothesis_compat import given, settings, st

from repro.core import (medusa_transpose, read_network_medusa,
                        write_network_medusa, read_network_oracle,
                        barrel_rotate)
from repro.core.analysis import InterconnectConfig, complexity_summary
from repro.models.common import softmax_xent, rope, pad_vocab


@settings(max_examples=25, deadline=None)
@given(st.sampled_from([2, 4, 8, 16, 32]))
def test_transpose_involution(n):
    x = jax.random.normal(jax.random.PRNGKey(n), (n, n, 3))
    np.testing.assert_array_equal(
        np.asarray(medusa_transpose(medusa_transpose(x, 0, 1), 0, 1)),
        np.asarray(x))


@settings(max_examples=25, deadline=None)
@given(st.sampled_from([2, 4, 8]), st.integers(1, 5), st.integers(1, 4))
def test_even_bandwidth_partition(n, g, w):
    """Every port receives exactly G lines of its own round-robin stream —
    the even static partition of paper obs. 1."""
    lines = jnp.arange(g * n * n * w, dtype=jnp.float32).reshape(g * n, n, w)
    banked = read_network_medusa(lines, n)
    for p in range(n):
        got = np.asarray(banked[:, :, p])           # port p's bank
        want = np.asarray(lines[p::n])
        np.testing.assert_array_equal(got, want)


@settings(max_examples=30, deadline=None)
@given(st.sampled_from([4, 8, 16]), st.integers(-40, 40))
def test_rotation_inverse(n, amt):
    x = jax.random.normal(jax.random.PRNGKey(abs(amt) + n), (n, 2))
    rot = barrel_rotate(x, amt % n)
    back = barrel_rotate(rot, (n - amt) % n)
    np.testing.assert_allclose(np.asarray(back), np.asarray(x))


@settings(max_examples=20, deadline=None)
@given(st.sampled_from([64, 128, 512]), st.sampled_from([4, 8, 16, 32]))
def test_mux_model_monotone(w_line, n):
    """Medusa never costs more muxes than baseline for N >= 2 (strictly less
    for N > 2) — the paper's complexity claim over the whole design space."""
    s = complexity_summary(InterconnectConfig(
        w_line=w_line, w_acc=w_line // n, n_read_ports=n, n_write_ports=n))
    if n > 2:
        assert s["medusa_mux_bits"] < s["baseline_mux_bits"]
    else:
        assert s["medusa_mux_bits"] <= s["baseline_mux_bits"]


@settings(max_examples=15, deadline=None)
@given(st.integers(2, 200))
def test_pad_vocab_multiple(v):
    p = pad_vocab(v)
    assert p % 128 == 0 and p >= v and p - v < 128


def test_xent_never_predicts_padding():
    logits = jnp.zeros((2, 3, 128))
    logits = logits.at[..., 100:].set(1e9)       # huge mass on padded slots
    targets = jnp.zeros((2, 3), jnp.int32)
    loss = softmax_xent(logits, targets, vocab_size=100)
    assert float(loss) < 10.0                    # padded entries masked out


@settings(max_examples=10, deadline=None)
@given(st.integers(1, 1000))
def test_rope_preserves_norm(pos):
    x = jax.random.normal(jax.random.PRNGKey(pos), (1, 1, 2, 64))
    y = rope(x, jnp.array([pos]), 10_000.0)
    np.testing.assert_allclose(np.linalg.norm(np.asarray(y)),
                               np.linalg.norm(np.asarray(x)), rtol=1e-5)
