"""MoE dispatch/combine on the burst contract.

Three levels:

* parity — the scatter-indexed dispatch write and gather-indexed combine
  read (``moe_apply(payload="burst")``) are bit-identical to the bare
  ``fabric.route`` reference across the pack × fold × kernel matrix, with
  the dispatch/combine words visible in :class:`SchedulerStats` and the
  ``tokens_dropped`` counter exact against a recomputed routing oracle;
* counter semantics — ``tokens_dropped`` is runtime-exact under jit (the
  debug callback fires once per executed dispatch, not once per trace);
* the ``aux_load_balance_loss`` regression — the load fraction counts every
  top-k assignment, matching a one-hot oracle on a batch where the old
  argmax (top-1) form provably disagrees.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import FabricConfig, ModelConfig, MoEConfig
from repro.fabric.scheduler import SchedulerStats
from repro.kernels import ops
from repro.models import moe
from repro.models.moe import aux_load_balance_loss, moe_apply, moe_params

KEY = jax.random.PRNGKey(0)


def _cfg(capacity_factor=4.0, pack="packed", fold="auto", **kw):
    base = dict(name="t", family="moe", n_layers=1, d_model=16, n_heads=2,
                n_kv_heads=2, d_ff=0, vocab_size=64,
                moe=MoEConfig(n_experts=4, top_k=2, expert_d_ff=32,
                              capacity_factor=capacity_factor),
                fabric=FabricConfig(n_ports=2, lane_width=8, pack=pack,
                                    word_fold=fold))
    base.update(kw)
    return ModelConfig(**base)


def _drop_oracle(p, x, cfg) -> int:
    """Recompute the capacity-dispatch keep mask exactly as ``moe_apply``
    ranks it and count the dropped assignments."""
    m = cfg.moe
    t = x.shape[0] * x.shape[1]
    xt = x.reshape(t, -1)
    probs = jax.nn.softmax(xt.astype(jnp.float32) @ p["router"], -1)
    a = np.asarray(jax.lax.top_k(probs, m.top_k)[1]).reshape(-1)
    cap = int(t * m.top_k * m.capacity_factor / m.n_experts) or 1
    rank = np.zeros_like(a)
    seen = {}
    for i, e in enumerate(a):          # stable within-expert rank
        rank[i] = seen.get(int(e), 0)
        seen[int(e)] = rank[i] + 1
    return int((rank >= cap).sum())


# ---------------------------------------------------------------------------
# dispatch/combine parity across the pack x fold x kernel matrix
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("pack", ("packed", "pad"))
@pytest.mark.parametrize("fold", (1, 2, "auto"))
@pytest.mark.parametrize("kernels", (False, True))
def test_moe_burst_route_parity(pack, fold, kernels):
    cfg = _cfg(capacity_factor=0.75, pack=pack, fold=fold)
    p = moe_params(KEY, cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(3), (2, 16, cfg.d_model))
    prev = ops.kernels_enabled()
    ops.use_kernels(kernels)
    try:
        stats = SchedulerStats()
        got = moe_apply(p, x, cfg, stats=stats, payload="burst")
        want = moe_apply(p, x, cfg, payload="route")
    finally:
        ops.use_kernels(prev)
    assert np.array_equal(np.asarray(got), np.asarray(want))   # bit parity
    # dispatch + combine each ran as one sparse-extent stream
    assert stats.streams_served == 2
    assert stats.flushes == 2
    assert stats.words_live > 0
    if kernels:
        assert stats.kernel_bursts == 2
    drops = _drop_oracle(p, x, cfg)
    assert drops > 0                    # the crafted capacity actually bites
    assert stats.tokens_dropped == drops


def test_moe_default_payload_rides_the_burst():
    """On a banking fabric with ``d_model % N == 0`` the default is the
    burst path (counted in stats); the ``fused`` fabric falls back to
    route.  Both equal the route reference."""
    cfg = _cfg()
    p = moe_params(KEY, cfg, jnp.float32)
    x = jax.random.normal(KEY, (2, 8, cfg.d_model))
    stats = SchedulerStats()
    got = moe_apply(p, x, cfg, stats=stats)
    assert stats.streams_served == 2    # default == burst on this geometry
    assert np.array_equal(np.asarray(got),
                          np.asarray(moe_apply(p, x, cfg, payload="route")))
    fused = _cfg(fabric=FabricConfig(n_ports=2, lane_width=8, impl="fused"))
    stats2 = SchedulerStats()
    got2 = moe_apply(p, x, fused, stats=stats2)
    assert stats2.streams_served == 0   # fused fabric: route fallback
    assert np.array_equal(np.asarray(got2),
                          np.asarray(moe_apply(p, x, fused, payload="route")))


def test_tokens_dropped_runtime_exact_under_jit():
    """The drop counter accumulates once per *execution*: two jitted calls
    (one trace) double the count, unlike the trace-time word counters."""
    cfg = _cfg(capacity_factor=0.75)
    p = moe_params(KEY, cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(3), (2, 16, cfg.d_model))
    stats = SchedulerStats()
    fn = jax.jit(lambda xx: moe_apply(p, xx, cfg))
    with moe.dispatch_stats(stats):
        fn(x).block_until_ready()
    fn(x).block_until_ready()           # cached trace, callback still fires
    jax.effects_barrier()
    drops = _drop_oracle(p, x, cfg)
    assert drops > 0
    assert stats.tokens_dropped == 2 * drops


# ---------------------------------------------------------------------------
# aux_load_balance_loss counts every top-k assignment
# ---------------------------------------------------------------------------

def _crafted_batch(cfg):
    """Every token's argmax is expert 0, second choices split 1/2: the
    top-1 load fraction is [1, 0, 0, 0] while the true top-2 fraction is
    [.5, .25, .25, 0] — the two forms provably disagree."""
    d, t = cfg.d_model, 8
    basis = np.eye(d, dtype=np.float32)
    router = np.zeros((d, cfg.moe.n_experts), np.float32)
    router[:4, :4] = np.eye(4) * 1.0
    rows = [10 * basis[0] + 9 * basis[1] if i % 2 else
            10 * basis[0] + 9 * basis[2] for i in range(t)]
    x = jnp.asarray(np.stack(rows)[None])             # [1, T, d]
    p = {"router": jnp.asarray(router)}
    return p, x


def test_aux_loss_counts_topk_assignments():
    cfg = _cfg()
    m = cfg.moe
    p, x = _crafted_batch(cfg)
    probs = jax.nn.softmax(
        x.reshape(-1, cfg.d_model).astype(jnp.float32) @ p["router"], -1)
    imp = np.asarray(jnp.mean(probs, axis=0))
    # one-hot oracle over ALL top-k assignments
    top_e = np.asarray(jax.lax.top_k(probs, m.top_k)[1]).reshape(-1)
    frac = np.bincount(top_e, minlength=m.n_experts) / top_e.size
    assert np.allclose(frac, [0.5, 0.25, 0.25, 0.0])
    want = m.n_experts * float(np.sum(frac * imp))
    got = float(aux_load_balance_loss(p, x, cfg))
    np.testing.assert_allclose(got, want, rtol=1e-6)
    # the old argmax (top-1) form disagrees on this batch
    top1 = np.asarray(jnp.argmax(probs, axis=-1))
    frac1 = np.bincount(top1, minlength=m.n_experts) / top1.size
    old = m.n_experts * float(np.sum(frac1 * imp))
    assert abs(got - old) > 1e-3
