import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref, ops
from repro.kernels.medusa_transpose import (medusa_transpose_tiles,
                                            read_network_tiles)
from repro.kernels.rotator import barrel_rotate_groups
from repro.kernels.stream_matmul import stream_matmul
from repro.core.transpose import read_network_oracle


@pytest.mark.parametrize("r,c,w,tile", [
    (8, 8, 4, 8), (16, 32, 8, 8), (32, 32, 128, 16), (64, 8, 2, 8),
    (128, 128, 16, 32)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16, jnp.int32])
def test_transpose_kernel_sweep(r, c, w, tile, dtype):
    x = jnp.arange(r * c * w).reshape(r, c, w).astype(dtype)
    out = medusa_transpose_tiles(x, tile=tile)
    np.testing.assert_array_equal(np.asarray(out),
                                  np.asarray(ref.transpose_ref(x)))


@pytest.mark.parametrize("r,c,w", [(7, 13, 5), (100, 36, 3), (1, 9, 2),
                                   (129, 64, 1)])
def test_transpose_wrapper_padding(r, c, w):
    x = jax.random.normal(jax.random.PRNGKey(r * c), (r, c, w))
    np.testing.assert_allclose(np.asarray(ops.transpose_rc(x)),
                               np.asarray(ref.transpose_ref(x)))


@pytest.mark.parametrize("n,g,w", [(8, 4, 4), (16, 2, 8), (32, 1, 16)])
def test_read_network_kernel(n, g, w):
    lines = jax.random.normal(jax.random.PRNGKey(0), (g * n, n, w))
    np.testing.assert_allclose(
        np.asarray(read_network_tiles(lines, n)),
        np.asarray(read_network_oracle(lines, n)))


@pytest.mark.parametrize("n,w", [(8, 4), (16, 2), (64, 8)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rotator_kernel(n, w, dtype):
    g = 5
    x = jax.random.normal(jax.random.PRNGKey(1), (g, n, w)).astype(dtype)
    amts = jnp.array([0, 1, n - 1, n, 3])
    out = barrel_rotate_groups(x, amts)
    for i in range(g):
        np.testing.assert_array_equal(
            np.asarray(out[i]),
            np.asarray(jnp.roll(x[i], -int(amts[i]) % n, axis=0)))


@pytest.mark.parametrize("m,k,n,dtype,tol", [
    (128, 128, 128, jnp.float32, 1e-5),
    (256, 384, 128, jnp.float32, 1e-5),
    (128, 256, 256, jnp.bfloat16, 2e-2)])
def test_stream_matmul(m, k, n, dtype, tol):
    x = jax.random.normal(jax.random.PRNGKey(2), (m, k)).astype(dtype)
    w = jax.random.normal(jax.random.PRNGKey(3), (k, n)).astype(dtype)
    out = stream_matmul(x, w, bm=128, bn=128, bk=128)
    want = ref.matmul_ref(x, w)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol * 10)


def test_kv_line_to_port():
    kv = jax.random.normal(jax.random.PRNGKey(4), (32, 8, 16))
    np.testing.assert_allclose(np.asarray(ops.kv_line_to_port(kv)),
                               np.asarray(ref.kv_layout_ref(kv)))


def test_ops_fallback_routing():
    was = ops.kernels_enabled()
    try:
        ops.use_kernels(False)
        x = jax.random.normal(jax.random.PRNGKey(5), (6, 10, 3))
        np.testing.assert_allclose(np.asarray(ops.transpose_rc(x)),
                                   np.asarray(ref.transpose_ref(x)))
    finally:
        ops.use_kernels(was)
