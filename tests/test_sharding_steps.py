"""Sharding rules + an end-to-end pjit step on a 1x1 CPU mesh (numerics)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_smoke
from repro.configs.base import TrainConfig, ShapeConfig
from repro.data import SyntheticLM
from repro.launch.mesh import compat_mesh
from repro.launch.steps import (build_train_step, build_prefill_step,
                                build_decode_step, make_sharder, param_specs,
                                zero1_specs, _eval_params)
from repro.models import api
from repro.parallel.sharding import Sharder, rules_for


def _mesh11():
    return compat_mesh(jax.devices()[:1], (1, 1), ("data", "model"))


def test_spec_mapping():
    s = Sharder(_mesh11(), rules_for("tp_heads"))
    assert s.spec("batch", "seq", "d_model") == P("data")
    assert s.spec("batch", None, "heads") == P("data", None, "model")
    # duplicate axis collapses
    assert s.spec("heads", "d_ff") == P("model")


def test_safe_spec_divisibility():
    s = Sharder(_mesh11(), rules_for("tp_heads"))
    # batch=1 cannot shard over data → dropped
    assert s.safe_spec((1, 8), ("batch", None)) == P()


def test_param_specs_cover_tree():
    cfg = get_smoke("starcoder2-15b")
    mesh = _mesh11()
    sharder = make_sharder(cfg, mesh)
    shapes = _eval_params(cfg)
    specs = param_specs(shapes, cfg, sharder)
    assert (jax.tree_util.tree_structure(specs)
            == jax.tree_util.tree_structure(shapes))


def test_zero1_adds_data_axis():
    mesh = compat_mesh(jax.devices()[:1], (1, 1), ("data", "model"))
    # fake 4-way data mesh via rules only (structure test, mesh is 1x1)
    cfg = get_smoke("stablelm-1.6b")
    sharder = make_sharder(cfg, mesh)
    shapes = _eval_params(cfg)
    pspecs = param_specs(shapes, cfg, sharder)
    zspecs = zero1_specs(pspecs, shapes, sharder)
    assert (jax.tree_util.tree_structure(zspecs)
            == jax.tree_util.tree_structure(pspecs))


@pytest.mark.parametrize("arch", ["stablelm-1.6b", "granite-moe-3b-a800m",
                                  "mamba2-780m", "recurrentgemma-2b"])
def test_train_step_numerics_on_mesh(arch):
    """The actual pjit train step (grad accum path) runs and reduces loss."""
    cfg = get_smoke(arch)
    mesh = _mesh11()
    shape = ShapeConfig("t", seq_len=16, global_batch=4, kind="train")
    tcfg = TrainConfig(lr=1e-2, warmup_steps=2, total_steps=100,
                       grad_accum=2, zero1=False)
    built = build_train_step(cfg, shape, mesh, tcfg)
    step = jax.jit(built.fn, in_shardings=built.in_shardings,
                   out_shardings=built.out_shardings,
                   donate_argnums=built.donate_argnums)
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    from repro.optim import init_opt_state
    state = {"params": params, "opt": init_opt_state(params, tcfg,
                                                     master=False)}
    data = SyntheticLM(cfg, batch=4, seq=16, seed=0)
    with mesh:
        losses = []
        for i in range(20):
            batch = {k: jnp.asarray(v) for k, v in data.batch_at(i).items()}
            state, metrics = step(state, batch)
            losses.append(float(metrics["loss"]))
    assert all(np.isfinite(losses))
    assert np.mean(losses[-5:]) < np.mean(losses[:5])


def test_serve_steps_on_mesh():
    cfg = get_smoke("gemma3-12b")
    mesh = _mesh11()
    shape = ShapeConfig("d", seq_len=32, global_batch=2, kind="decode")
    pshape = ShapeConfig("p", seq_len=16, global_batch=2, kind="prefill")
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    pre = build_prefill_step(cfg, pshape, mesh)
    dec = build_decode_step(cfg, shape, mesh)
    with mesh:
        pre_fn = jax.jit(pre.fn, in_shardings=pre.in_shardings,
                         out_shardings=pre.out_shardings)
        logits, caches = pre_fn(params, {"tokens": jnp.zeros((2, 16),
                                                             jnp.int32)})
        assert np.isfinite(np.asarray(logits, np.float32)).all()
        # decode built for t_max=32 but prefill cache is 16 — rebuild cache
        caches = api.init_cache(cfg, 2, 32)
        dec_fn = jax.jit(dec.fn, in_shardings=dec.in_shardings,
                         out_shardings=dec.out_shardings,
                         donate_argnums=dec.donate_argnums)
        l2, caches = dec_fn(params, caches, jnp.zeros((2, 1), jnp.int32),
                            jnp.int32(16))
        assert np.isfinite(np.asarray(l2, np.float32)).all()
