import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke
from repro.configs.base import TrainConfig
from repro.data import SyntheticLM
from repro.optim import (init_opt_state, adamw_update, lr_schedule,
                         global_norm, clip_by_global_norm)


def test_adamw_minimises_quadratic():
    tcfg = TrainConfig(lr=0.1, warmup_steps=1, total_steps=200,
                       weight_decay=0.0, grad_clip=1e9)
    target = jnp.array([1.0, -2.0, 3.0])
    params = {"w": jnp.zeros(3)}
    state = init_opt_state(params, tcfg)
    loss = lambda p: jnp.sum((p["w"] - target) ** 2)
    for _ in range(200):
        grads = jax.grad(loss)(params)
        params, state, _ = adamw_update(grads, state, params, tcfg)
    assert float(loss(params)) < 1e-2


def test_clipping_bounds_norm():
    grads = {"a": jnp.full((10,), 100.0)}
    clipped, norm = clip_by_global_norm(grads, 1.0)
    assert abs(float(global_norm(clipped)) - 1.0) < 1e-5
    assert float(norm) > 100.0


def test_lr_schedule_shape():
    tcfg = TrainConfig(lr=1e-3, warmup_steps=10, total_steps=100)
    lrs = [float(lr_schedule(jnp.int32(s), tcfg)) for s in range(100)]
    assert lrs[0] == 0.0
    assert abs(lrs[10] - 1e-3) < 1e-4          # peak after warmup
    assert lrs[99] < lrs[50] < lrs[10]         # decays
    assert lrs[99] >= 1e-4 - 1e-6              # floor at 10%


def test_master_weights_roundtrip():
    tcfg = TrainConfig(lr=1e-2, warmup_steps=1, grad_clip=1e9,
                       weight_decay=0.0)
    params = {"w": jnp.ones(4, jnp.bfloat16)}
    state = init_opt_state(params, tcfg, master=True)
    grads = {"w": jnp.full(4, 1e-3, jnp.bfloat16)}
    p2, s2, _ = adamw_update(grads, state, params, tcfg)
    assert p2["w"].dtype == jnp.bfloat16
    assert s2.master["w"].dtype == jnp.float32
    # master accumulates updates too small for bf16 params to see alone
    assert not np.allclose(np.asarray(s2.master["w"]), 1.0)


def test_data_deterministic_and_resumable():
    cfg = get_smoke("starcoder2-15b")
    d1 = SyntheticLM(cfg, batch=4, seq=32, seed=7)
    d2 = SyntheticLM(cfg, batch=4, seq=32, seed=7)
    b1, b2 = d1.batch_at(123), d2.batch_at(123)   # fresh instance, same step
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert not np.array_equal(d1.batch_at(0)["tokens"],
                              d1.batch_at(1)["tokens"])


def test_data_host_sharding_differs():
    cfg = get_smoke("starcoder2-15b")
    a = SyntheticLM(cfg, batch=2, seq=16, seed=0, host_id=0, num_hosts=2)
    b = SyntheticLM(cfg, batch=2, seq=16, seed=0, host_id=1, num_hosts=2)
    assert not np.array_equal(a.batch_at(0)["tokens"], b.batch_at(0)["tokens"])


def test_data_learnable_structure():
    """Bigram chain: successor sets are small → an oracle predicting from the
    table beats chance by a wide margin (the stream is learnable)."""
    cfg = get_smoke("starcoder2-15b")
    d = SyntheticLM(cfg, batch=8, seq=64, seed=3, branching=4)
    b = d.batch_at(0)
    hits = 0
    total = 0
    for row_t, row_y in zip(b["tokens"], b["targets"]):
        for t, y in zip(row_t, row_y):
            hits += int(y in d._table[t])
            total += 1
    assert hits / total > 0.99


def test_modality_stubs():
    vcfg = get_smoke("internvl2-1b")
    vb = SyntheticLM(vcfg, batch=2, seq=16).batch_at(0)
    assert vb["patch_embeds"].shape == (2, vcfg.n_patches, vcfg.d_model)
    assert vb["tokens"].shape[1] == 16 - vcfg.n_patches
    acfg = get_smoke("whisper-medium")
    ab = SyntheticLM(acfg, batch=2, seq=16).batch_at(0)
    assert ab["frames"].shape == (2, acfg.encoder_seq, acfg.d_model)
