"""Deterministic synthetic data pipeline.

Tokens are drawn from a fixed random **bigram chain** (per-seed transition
table), so the stream has learnable structure — examples demonstrably reduce
loss — while remaining fully deterministic and *resumable from any step*
(generation is a pure function of (seed, step, host)).  In a multi-host
deployment each host generates only its shard: ``host_id``/``num_hosts``
partition the global batch, so there is no data redistribution at scale.

Modality stubs (assignment): VLM configs get deterministic ``patch_embeds``,
audio configs get ``frames`` — the precomputed frontend outputs.
"""

from __future__ import annotations

import dataclasses
from typing import Iterator, Optional

import numpy as np

from repro.configs.base import ModelConfig


@dataclasses.dataclass
class SyntheticLM:
    cfg: ModelConfig
    batch: int                      # per-host batch
    seq: int
    seed: int = 0
    host_id: int = 0
    num_hosts: int = 1
    branching: int = 4              # bigram fan-out (lower = easier task)
    vocab_limit: int = 0            # draw tokens from [0, limit) (0 = full
                                    # vocab); small limits make the chain
                                    # learnable in few steps (examples/tests)

    def __post_init__(self):
        rng = np.random.RandomState(self.seed)
        v = self.vocab_limit or self.cfg.vocab_size
        self._v = v
        # fixed bigram table: each token transitions to `branching` successors
        self._table = rng.randint(0, v, size=(v, self.branching))

    def batch_at(self, step: int) -> dict:
        """The batch for a given global step (pure function — resumable)."""
        rng = np.random.RandomState(
            (self.seed * 1_000_003 + step) * 31 + self.host_id)
        v = self._v
        b, s = self.batch, self.seq
        toks = np.empty((b, s + 1), np.int32)
        toks[:, 0] = rng.randint(0, v, size=b)
        choices = rng.randint(0, self.branching, size=(b, s))
        for t in range(s):
            toks[:, t + 1] = self._table[toks[:, t], choices[:, t]]
        out = {"tokens": toks[:, :-1], "targets": toks[:, 1:]}
        if self.cfg.n_patches:
            # VLM: n_patches stub patch embeddings + (s - n_patches) text
            # tokens; loss is over text positions (api.loss_fn slices).
            text = s - self.cfg.n_patches
            out["patch_embeds"] = rng.randn(
                b, self.cfg.n_patches, self.cfg.d_model).astype(np.float32)
            out["tokens"] = toks[:, :text]
            out["targets"] = toks[:, 1:text + 1]
        if self.cfg.family == "audio":
            out["frames"] = rng.randn(
                b, self.cfg.encoder_seq, self.cfg.d_model).astype(np.float32)
        return out

    def __iter__(self) -> Iterator[dict]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


def batch_lines(tokens: np.ndarray, n_ports: int) -> np.ndarray:
    """Pack a ``[B, S]`` token batch into fabric DRAM lines ``[L, N]``.

    Host→HBM staging expressed in the fabric's units: the flattened batch is
    padded to whole N-line groups (L a multiple of N, one N-word line per
    row) so it can ride the shared read network as one more logical stream
    of the burst scheduler (``benchmarks/fabric_unified.py``).  Padding is
    zeros; the consumer slices ``B*S`` tokens back off the port streams.
    """
    flat = np.asarray(tokens).reshape(-1)
    group = n_ports * n_ports
    pad = (-flat.size) % group
    if pad:
        flat = np.concatenate([flat, np.zeros((pad,), flat.dtype)])
    return flat.reshape(-1, n_ports)


def make_batch_specs(cfg: ModelConfig, batch: int, seq: int,
                     kind: str = "train"):
    """ShapeDtypeStruct stand-ins for every model input of a step (the
    pattern used by the dry-run: weak-type-correct, no allocation)."""
    import jax
    import jax.numpy as jnp
    text = seq - (cfg.n_patches or 0) if kind != "decode" else seq
    specs = {}
    if kind == "train":
        specs["tokens"] = jax.ShapeDtypeStruct((batch, text), jnp.int32)
        specs["targets"] = jax.ShapeDtypeStruct((batch, text), jnp.int32)
    elif kind == "prefill":
        specs["tokens"] = jax.ShapeDtypeStruct((batch, text), jnp.int32)
    if cfg.n_patches and kind != "decode":
        specs["patch_embeds"] = jax.ShapeDtypeStruct(
            (batch, cfg.n_patches, cfg.d_model), jnp.float32)
    if cfg.family == "audio" and kind != "decode":
        specs["frames"] = jax.ShapeDtypeStruct(
            (batch, cfg.encoder_seq, cfg.d_model), jnp.float32)
    return specs
