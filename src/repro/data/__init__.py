from repro.data.pipeline import SyntheticLM, batch_lines, make_batch_specs

__all__ = ["SyntheticLM", "batch_lines", "make_batch_specs"]
