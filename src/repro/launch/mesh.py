"""Production mesh definition.

``make_production_mesh`` is a FUNCTION (importing this module never touches
jax device state).  The single-pod mesh is 16x16 = 256 chips (data x model);
the multi-pod mesh is 2x16x16 = 512 chips with a leading "pod" axis that the
sharding rules fold into data parallelism (gradient all-reduce crosses pods).
The mesh is parametric: ``make_mesh_shape`` scales to larger deployments
(e.g. (8, 16, 32) = 4096 chips) with the same sharding rules.
"""

from __future__ import annotations

import math

import jax
import numpy as np


def compat_mesh(devices, shape: tuple, axes: tuple):
    """Construct a Mesh with Auto axis types where this jax supports them
    (axis_types landed after 0.4.x; Auto is the default behaviour there)."""
    arr = np.asarray(devices).reshape(shape)
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        return jax.sharding.Mesh(arr, axes,
                                 axis_types=(axis_type.Auto,) * len(axes))
    return jax.sharding.Mesh(arr, axes)


def compat_shard_map(f, mesh, in_specs, out_specs, check_vma=None):
    """``jax.shard_map`` where available, ``jax.experimental.shard_map``
    otherwise (same semantics; ``check_vma`` was called ``check_rep``)."""
    if hasattr(jax, "shard_map"):
        kw = {} if check_vma is None else {"check_vma": check_vma}
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, **kw)
    from jax.experimental.shard_map import shard_map as _sm
    kw = {} if check_vma is None else {"check_rep": check_vma}
    return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kw)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = math.prod(shape)
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"mesh {shape} needs {n} devices, have {len(devices)} — the "
            f"dry-run must set XLA_FLAGS=--xla_force_host_platform_device_count"
            f"={n} before any jax import")
    return compat_mesh(devices[:n], shape, axes)


def make_mesh(shape: tuple, axes: tuple):
    """Parametric mesh for scale studies (same rules, any chip count)."""
    n = math.prod(shape)
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"mesh {shape} needs {n} devices, have {len(devices)} — set "
            f"XLA_FLAGS=--xla_force_host_platform_device_count={n} before "
            f"any jax import")
    return compat_mesh(devices[:n], shape, axes)


# TPU v5e hardware constants used by the roofline analysis (§Roofline).
PEAK_FLOPS_BF16 = 197e12          # per chip
HBM_BW = 819e9                    # bytes/s per chip
ICI_BW = 50e9                     # bytes/s per link
