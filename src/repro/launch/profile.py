"""Per-op cost breakdown of a compiled module — the §Perf instrument.

``breakdown(compiled_text)`` returns the trip-count-corrected byte/flop/
collective contribution of every op (same model as ``hlo_analysis``), sorted
by HBM traffic.  This is what drove every hillclimbing hypothesis in
EXPERIMENTS.md §Perf; promoted to the library so future iterations don't
re-derive it.

CLI:  PYTHONPATH=src python -m repro.launch.profile --arch <id> --shape <s>
          [--set key=value ...] [--top 15]
"""

from __future__ import annotations

import collections
import dataclasses
from typing import Optional

from repro.launch import hlo_analysis as H


@dataclasses.dataclass
class OpCost:
    op: str
    line: str
    bytes: float = 0.0
    flops: float = 0.0
    collective_bytes: float = 0.0


def breakdown(hlo_text: str):
    """→ (list[OpCost] sorted by bytes desc, totals dict)."""
    comps = H.parse_computations(hlo_text)
    entry = comps.pop("__entry__")[0]
    symtabs = {n: H._symtab(l) for n, l in comps.items()}
    dimstabs = {n: H._symtab_dims(l) for n, l in comps.items()}
    tally: dict = collections.defaultdict(
        lambda: OpCost("", ""))

    def add(key, op, line, **kw):
        c = tally[key]
        c.op, c.line = op, line
        for k, v in kw.items():
            setattr(c, k, getattr(c, k) + v)

    def walk(name, mult, flops_only=False):
        tab = symtabs.get(name, {})
        dtab = dimstabs.get(name, {})
        for ln in comps.get(name, ()):
            res, op, operands = H._split_op(ln)
            rhs = ln.split("=", 1)[1]
            key = ln[:120]
            if op == "while":
                m = H._WHILE_RE.search(ln)
                mt = H._TRIP_RE.search(ln)
                if m:
                    walk(m.group(2),
                         mult * (int(mt.group(1)) if mt else 1), flops_only)
                continue
            if op == "conditional":
                mb = H._BRANCHES_RE.search(ln)
                if mb:
                    for b in mb.group(1).split(","):
                        walk(b.strip().lstrip("%"), mult, flops_only)
                continue
            coll = next((c for c in H.COLLECTIVE_OPS
                         if f" {c}(" in rhs or f" {c}-start(" in rhs), None)
            if coll and not flops_only:
                p = H._collective_payload(ln, tab) * mult
                add(key, coll, ln, collective_bytes=p,
                    bytes=H._op_bytes(ln, tab) * mult)
                continue
            if op == "fusion":
                mc = H._CALLS_RE.search(ln)
                if mc:
                    walk(mc.group(1), mult, flops_only=True)
                if not flops_only:
                    b = H._fusion_bytes(
                        ln, tab, comps.get(mc.group(1), []) if mc else [],
                        symtabs.get(mc.group(1), {}) if mc else {}) * mult
                    add(key, op, ln, bytes=b)
                continue
            if op == "dot":
                add(key, op, ln, flops=H._dot_flops(ln, dtab) * mult)
            if flops_only:
                continue
            if any(s in rhs for s in H._SKIP_OPS):
                continue
            add(key, op, ln, bytes=H._op_bytes(ln, tab) * mult)

    if entry:
        walk(entry, 1.0)
    costs = sorted(tally.values(), key=lambda c: -c.bytes)
    totals = {
        "bytes": sum(c.bytes for c in costs),
        "flops": sum(c.flops for c in costs),
        "collective_bytes": sum(c.collective_bytes for c in costs),
    }
    return costs, totals


def print_breakdown(costs, totals, top: int = 15,
                    hbm_bw: float = 819e9, link_bw: float = 50e9):
    print(f"memory {totals['bytes']:.3e} B = {totals['bytes']/hbm_bw:.4f}s | "
          f"flops {totals['flops']:.3e} | "
          f"collective {totals['collective_bytes']:.3e} B = "
          f"{totals['collective_bytes']/link_bw:.4f}s")
    for c in costs[:top]:
        share = c.bytes / totals["bytes"] * 100 if totals["bytes"] else 0
        print(f"{c.bytes:10.3e} ({share:4.1f}%) {c.op:18s} {c.line[:78]}")


def _main():
    import os
    os.environ.setdefault("XLA_FLAGS",
                          "--xla_force_host_platform_device_count=512")
    os.environ.setdefault("REPRO_NO_KERNELS", "1")
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--set", nargs="*", default=[],
                    help="config overrides key=value (e.g. kv_layout=fused)")
    ap.add_argument("--top", type=int, default=15)
    args = ap.parse_args()

    import jax
    from repro.configs import SHAPES
    from repro.launch.dryrun import cell_config
    from repro.launch.mesh import make_production_mesh
    from repro.launch.steps import build_step

    cfg = cell_config(args.arch, args.shape)
    overrides = {}
    for kv in args.set:
        k, v = kv.split("=", 1)
        overrides[k] = {"true": True, "false": False}.get(
            v.lower(), int(v) if v.isdigit() else v)
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    mesh = make_production_mesh(multi_pod=args.multi_pod)
    with mesh:
        built = build_step(cfg, SHAPES[args.shape], mesh)
        compiled = jax.jit(
            built.fn, in_shardings=built.in_shardings,
            out_shardings=built.out_shardings,
            donate_argnums=built.donate_argnums,
        ).lower(*built.input_specs).compile()
    costs, totals = breakdown(compiled.as_text())
    print_breakdown(costs, totals, top=args.top)


if __name__ == "__main__":
    _main()
