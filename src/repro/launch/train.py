"""Training driver: ``python -m repro.launch.train --arch <id> [...]``.

Production path: builds the pjit train step for the requested mesh, wires the
fault-tolerant runner (checkpoint/restart + straggler detection) around it,
and streams the deterministic synthetic pipeline.  On this CPU container use
``--smoke`` (reduced config, 1x1 mesh) — the same code path end to end.
"""

from __future__ import annotations

import argparse
import dataclasses
import logging
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_smoke, TrainConfig
from repro.configs.base import ShapeConfig
from repro.checkpoint import CheckpointManager, latest_step, restore_checkpoint
from repro.data import SyntheticLM
from repro.launch.steps import build_train_step
from repro.models import api
from repro.optim import init_opt_state
from repro.runtime import TrainingRunner, StragglerDetector, FaultInjector


def make_mesh_for(args):
    if args.smoke:
        from repro.launch.mesh import compat_mesh
        return compat_mesh(jax.devices()[:1], (1, 1), ("data", "model"))
    from repro.launch.mesh import make_production_mesh
    return make_production_mesh(multi_pod=args.multi_pod)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm-1.6b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--fail-at", type=int, nargs="*", default=[],
                    help="inject node failures at these steps (FT demo)")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()
    logging.basicConfig(level=logging.INFO)

    cfg = get_smoke(args.arch) if args.smoke else get_config(args.arch)
    mesh = make_mesh_for(args)
    shape = ShapeConfig("cli", args.seq, args.batch, "train")
    tcfg = TrainConfig(lr=args.lr, warmup_steps=max(args.steps // 20, 1),
                       total_steps=args.steps, grad_accum=args.grad_accum,
                       zero1=not args.smoke, checkpoint_dir=args.ckpt_dir,
                       checkpoint_every=args.ckpt_every)

    built = build_train_step(cfg, shape, mesh, tcfg)
    jit_step = jax.jit(built.fn, in_shardings=built.in_shardings,
                       out_shardings=built.out_shardings,
                       donate_argnums=built.donate_argnums)

    params = api.init_params(cfg, jax.random.PRNGKey(tcfg.seed))
    state = {"params": params,
             "opt": init_opt_state(params, tcfg, master=False)}
    start = 0
    ckpt = CheckpointManager(args.ckpt_dir, every=args.ckpt_every, keep=3)
    if args.resume and (last := latest_step(args.ckpt_dir)) is not None:
        state, extra = restore_checkpoint(args.ckpt_dir, last, state)
        start = extra.get("data_step", last)
        print(f"resumed from step {start}")

    data = SyntheticLM(cfg, batch=args.batch, seq=args.seq, seed=tcfg.seed)

    def step_fn(state, batch):
        with mesh:
            return jit_step(state, {k: jnp.asarray(v)
                                    for k, v in batch.items()})

    t0 = time.time()

    def on_metrics(step, metrics):
        if step % args.log_every == 0:
            print(f"step {step:5d} loss {float(metrics['loss']):.4f} "
                  f"lr {float(metrics['lr']):.2e} "
                  f"gnorm {float(metrics['grad_norm']):.2f} "
                  f"({(time.time() - t0) / max(step - start, 1):.2f}s/step)",
                  flush=True)

    runner = TrainingRunner(
        step_fn, data, ckpt, straggler=StragglerDetector(),
        fault_injector=FaultInjector(tuple(args.fail_at)) if args.fail_at
        else None)
    state, end = runner.run(state, start, args.steps, on_metrics=on_metrics)
    print(f"done at step {end}; restarts={runner.restarts}, "
          f"stragglers flagged={runner.straggler.flagged}")


if __name__ == "__main__":
    main()
