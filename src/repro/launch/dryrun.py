import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any jax import: jax locks the device
# count on first init, and the production meshes below need 512 host devices.
os.environ.setdefault("REPRO_NO_KERNELS", "1")   # dry-run lowers XLA-native HLO

"""Multi-pod dry-run driver (deliverable e).

For every (architecture x input-shape) cell and both production meshes
(single-pod 16x16 = 256 chips, multi-pod 2x16x16 = 512 chips), lower and
compile the appropriate step (train_step / prefill / serve_step) from
ShapeDtypeStruct stand-ins — no allocation — then record:

* ``compiled.memory_analysis()``  (per-device bytes — proves it fits),
* ``compiled.cost_analysis()``    (XLA's own numbers, loop bodies counted 1x),
* trip-count-corrected FLOPs / HBM bytes / collective bytes from our HLO
  parse (`hlo_analysis.analyze_hlo`),
* the three roofline terms + dominant bottleneck (§Roofline),
* MODEL_FLOPS = 6·N·D (train) and the useful-compute ratio.

Results are cached as JSON per cell under ``results/dryrun`` so the sweep is
resumable; failures are recorded with tracebacks (a failure here is a bug in
the system, per the assignment).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun [--arch A] [--shape S]
      [--mesh single|multi|both] [--force] [--list]
"""

import argparse
import dataclasses
import json
import time
import traceback

import jax

from repro.configs import ARCHS, SHAPES, get_config
from repro.configs.base import ModelConfig
from repro.launch.mesh import (make_production_mesh, PEAK_FLOPS_BF16, HBM_BW,
                               ICI_BW)
from repro.launch.hlo_analysis import analyze_hlo, model_flops, roofline_terms
from repro.launch.steps import build_step

RESULTS_DIR = os.environ.get(
    "REPRO_RESULTS_DIR",
    os.path.join(os.path.dirname(__file__), "..", "..", "..",
                 "results", "dryrun"))


def cell_config(arch: str, shape_name: str) -> ModelConfig:
    """Per-cell config adjustments (documented in DESIGN.md §7):
    long_500k always runs sequence-parallel so the KV/state shards."""
    cfg = get_config(arch)
    if shape_name == "long_500k" and cfg.sharding_profile == "tp_heads":
        cfg = dataclasses.replace(cfg, sharding_profile="sp_seq")
    return cfg


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             overrides: dict | None = None) -> dict:
    cfg = cell_config(arch, shape_name)
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.devices.size
    t0 = time.time()
    with mesh:
        built = build_step(cfg, shape, mesh)
        jitted = jax.jit(built.fn,
                         in_shardings=built.in_shardings,
                         out_shardings=built.out_shardings,
                         donate_argnums=built.donate_argnums)
        lowered = jitted.lower(*built.input_specs)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis() or {}
    if isinstance(cost, list):      # older jax: one dict per device
        cost = cost[0] if cost else {}
    costs = analyze_hlo(compiled.as_text())
    roof = roofline_terms(costs, chips, PEAK_FLOPS_BF16, HBM_BW, ICI_BW)
    mf = model_flops(cfg, shape)
    hlo_total_flops = costs.flops * chips
    result = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "chips": chips,
        "status": "ok",
        "sharding_profile": cfg.sharding_profile,
        "memory": {
            "bytes_per_device": getattr(mem, "temp_size_in_bytes", 0)
                                 + getattr(mem, "argument_size_in_bytes", 0),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "peak_bytes": getattr(mem, "peak_memory_in_bytes", None),
        },
        "xla_cost_analysis": {k: cost.get(k) for k in
                              ("flops", "bytes accessed", "transcendentals")},
        "parsed": costs.as_dict(),
        "roofline": roof,
        "model_flops": mf,
        "useful_compute_ratio": (mf / hlo_total_flops
                                 if hlo_total_flops else None),
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
    }
    return result


def cell_path(arch, shape_name, multi_pod):
    mesh = "multi" if multi_pod else "single"
    return os.path.join(RESULTS_DIR, f"{arch}__{shape_name}__{mesh}.json")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--list", action="store_true")
    args = ap.parse_args()

    os.makedirs(RESULTS_DIR, exist_ok=True)
    archs = [args.arch] if args.arch else list(ARCHS)
    shapes = [args.shape] if args.shape else list(SHAPES)
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    todo, done, skipped = [], 0, 0
    for arch in archs:
        cfg = get_config(arch)
        for shape_name in shapes:
            if shape_name == "long_500k" and not cfg.subquadratic:
                skipped += 1
                continue
            for mp in meshes:
                path = cell_path(arch, shape_name, mp)
                if os.path.exists(path) and not args.force:
                    with open(path) as f:
                        if json.load(f).get("status") == "ok":
                            done += 1
                            continue
                todo.append((arch, shape_name, mp))

    print(f"dry-run: {len(todo)} to run, {done} cached, "
          f"{skipped} long_500k skips (full-attention archs)")
    if args.list:
        for t in todo:
            print("  ", t)
        return

    for i, (arch, shape_name, mp) in enumerate(todo):
        tag = f"{arch} x {shape_name} x {'2x16x16' if mp else '16x16'}"
        print(f"[{i+1}/{len(todo)}] {tag} ...", flush=True)
        try:
            res = run_cell(arch, shape_name, mp)
            r = res["roofline"]
            print(f"    ok: compute={r['compute_s']:.3e}s "
                  f"memory={r['memory_s']:.3e}s coll={r['collective_s']:.3e}s "
                  f"dominant={r['dominant']} "
                  f"mem/dev={res['memory']['peak_bytes'] or 0:.3e}B "
                  f"(lower {res['lower_s']}s compile {res['compile_s']}s)",
                  flush=True)
        except Exception as e:  # record failures — they are system bugs
            res = {"arch": arch, "shape": shape_name,
                   "mesh": "2x16x16" if mp else "16x16",
                   "status": "error", "error": repr(e),
                   "traceback": traceback.format_exc()}
            print(f"    ERROR: {e!r}", flush=True)
        with open(cell_path(arch, shape_name, mp), "w") as f:
            json.dump(res, f, indent=1, default=str)


if __name__ == "__main__":
    main()
