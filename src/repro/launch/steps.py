"""Step builders: pjit-ready train/prefill/decode steps with full sharding.

This is where logical axes meet the mesh: parameter leaves get PartitionSpecs
by name (stacked-layer and expert dims handled), optimizer state gets ZeRO-1
data-axis sharding, caches get batch/heads/seq sharding per profile, and the
steps are wrapped with ``use_sharder`` so activation constraints resolve.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import (FabricConfig, ModelConfig, ShapeConfig,
                                TrainConfig)
from repro.data.pipeline import make_batch_specs
from repro.models import api
from repro.optim import OptState, init_opt_state, adamw_update
from repro.parallel.sharding import Sharder, rules_for, use_sharder

# ---------------------------------------------------------------------------
# parameter logical axes by leaf name
# ---------------------------------------------------------------------------

# name → logical axes for the *unstacked* leaf.  "attn_io" is the TP axis of
# attention projections, "moe_ff" the per-expert FFN axis (moe_cap profile).
PARAM_AXES_2D = {
    "table": ("vocab", "d_model"),
    "head": ("d_model", "vocab"),
    "wq": ("d_model", "attn_io"), "wk": ("d_model", "attn_io"),
    "wv": ("d_model", "attn_io"), "wo": ("attn_io", "d_model"),
    "w_gate": ("d_model", "d_ff"), "w_up": ("d_model", "d_ff"),
    "w_out": ("d_ff", "d_model"),
    "w_xz": ("d_model", "inner"), "w_bc": ("d_model", None),
    "w_dt": ("d_model", None),
    "w_branch": ("d_model", "inner"), "w_a": ("inner", "inner_out"),
    "w_i": ("inner", "inner_out"), "router": ("d_model", None),
    "conv_w": (None, "inner"),
}
PARAM_AXES_MOE_3D = {                    # [experts, in, out]
    "w_gate": ("experts", "d_model", "moe_ff"),
    "w_up": ("experts", "d_model", "moe_ff"),
    "w_out": ("experts", "moe_ff", "d_model"),
}
PARAM_AXES_1D = {
    "conv_b": ("inner",), "gate_norm": ("inner",), "lam": ("inner",),
    "b_a": ("inner",), "b_i": ("inner",),
}

# extra rules appended to every profile
EXTRA_RULES = {"attn_io": "model", "inner_out": None, "moe_ff": None}
EXTRA_RULES_MOE_CAP = {"attn_io": "model", "inner_out": None,
                       "moe_ff": "model"}


def resolve_fabric(cfg: ModelConfig, shape: ShapeConfig) -> FabricConfig:
    """Validate the model's fabric against a serving shape at build time.

    The decode cache is a [B, T, Hkv, D] line stream whose line width must
    be the fabric's W_line (one timestep across the port heads) — catching
    geometry errors here costs nothing; inside the jitted step they surface
    as shape errors deep in the layer scan.  The burst packing mode
    (``FabricConfig.pack``) and — for decode shapes — the paged-pool page
    geometry are validated on the same path.  Pure validator: page clamping
    to the cache depth happens where pages are allocated
    (``ServingEngine.__init__``).
    """
    fab = cfg.resolved_fabric
    has_attn = any(t in ("A", "L") for t in cfg.layer_types())
    if cfg.fabric is not None and has_attn and cfg.n_kv_heads:
        want = cfg.n_kv_heads * cfg.resolved_head_dim
        if fab.line_width != want:
            raise ValueError(
                f"{cfg.name}: fabric W_line={fab.line_width} does not match "
                f"the KV line (n_kv_heads*head_dim={want})")
        if (fab.paged_pool and shape.kind == "decode"
                and fab.page_size > shape.seq_len):
            # a page deeper than the whole cache can only be a config error:
            # the engine would clamp it, but an explicit fabric asking for
            # it at a decode shape deserves a loud failure at build time
            raise ValueError(
                f"{cfg.name}: fabric page_size={fab.page_size} exceeds the "
                f"decode cache depth ({shape.name}: seq_len={shape.seq_len})")
    return fab


def make_sharder(cfg: ModelConfig, mesh) -> Sharder:
    rules = rules_for(cfg.sharding_profile)
    rules.update(EXTRA_RULES_MOE_CAP if cfg.sharding_profile == "moe_cap"
                 else EXTRA_RULES)
    return Sharder(mesh, rules)


def _leaf_logical_axes(path, leaf, cfg: ModelConfig):
    names = [getattr(k, "key", getattr(k, "name", None))
             for k in path if hasattr(k, "key") or hasattr(k, "name")]
    name = names[-1] if names else None
    stacked = 1 if (names and names[0] in ("unit", "encoder", "decoder")) else 0
    is_moe = "ffn" in names and cfg.moe is not None and leaf.ndim - stacked == 3
    core = leaf.ndim - stacked
    if is_moe and name in PARAM_AXES_MOE_3D:
        axes = PARAM_AXES_MOE_3D[name]
    elif core == 2 and name in PARAM_AXES_2D:
        axes = PARAM_AXES_2D[name]
    elif core == 1 and name in PARAM_AXES_1D:
        axes = PARAM_AXES_1D[name]
    else:
        axes = (None,) * core
    return (None,) * stacked + tuple(axes)


def param_specs(params, cfg: ModelConfig, sharder: Sharder):
    """PartitionSpec pytree for a parameter tree (respects divisibility)."""
    def one(path, leaf):
        logical = _leaf_logical_axes(path, leaf, cfg)
        return sharder.safe_spec(leaf.shape, logical)
    return jax.tree_util.tree_map_with_path(one, params)


def zero1_specs(pspecs, params, sharder: Sharder):
    """ZeRO-1: additionally shard optimizer-state leaves over the data axis
    (first dim that is free and divisible)."""
    data_axes = tuple(a for a in ("pod", "data")
                      if a in sharder.mesh.axis_names)
    sizes = dict(zip(sharder.mesh.axis_names, sharder.mesh.devices.shape))
    dp = int(np.prod([sizes[a] for a in data_axes]))

    def one(spec, leaf):
        entries = list(tuple(spec) + (None,) * (leaf.ndim - len(tuple(spec))))
        for i, (dim, e) in enumerate(zip(leaf.shape, entries)):
            if e is None and dim % dp == 0 and dim > 0 and dp > 1:
                entries[i] = data_axes if len(data_axes) > 1 else data_axes[0]
                return sharder._spec_from_axes(entries)
        return spec
    return jax.tree_util.tree_map(one, pspecs, params)


def cache_specs(caches, cfg: ModelConfig, sharder: Sharder):
    """PartitionSpecs for decode caches by leaf name."""
    def one(path, leaf):
        names = [getattr(k, "key", None) for k in path if hasattr(k, "key")]
        name = names[-1] if names else None
        stacked = 1 if (names and names[0] in ("unit",)) or leaf.ndim >= 4 else 0
        if name in ("k", "v"):
            logical = (None, "batch", "kv_seq", "kv_heads", None)[
                5 - leaf.ndim:]
        elif name in ("cross_k", "cross_v"):
            logical = (None, "batch", "kv_heads", "frames", None)[
                5 - leaf.ndim:]
        elif name == "state":
            logical = (None, "batch", "inner", None, None)[5 - leaf.ndim:]
        elif name == "conv":
            logical = (None, "batch", None, "inner")[4 - leaf.ndim:]
        elif name == "h":
            logical = (None, "batch", "inner")[3 - leaf.ndim:]
        else:
            logical = (None,) * leaf.ndim
        return sharder.safe_spec(leaf.shape, logical)
    return jax.tree_util.tree_map_with_path(one, caches)


def batch_specs_sharding(batch_specs, sharder: Sharder):
    def one(leaf):
        logical = ("batch",) + (None,) * (len(leaf.shape) - 1)
        return sharder.safe_spec(leaf.shape, logical)
    return jax.tree_util.tree_map(one, batch_specs)


# ---------------------------------------------------------------------------
# step builders
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class BuiltStep:
    fn: object                    # the python step callable (to be jitted)
    in_shardings: object
    out_shardings: object
    input_specs: tuple            # ShapeDtypeStructs for .lower()
    donate_argnums: tuple = ()


def _eval_params(cfg: ModelConfig):
    return jax.eval_shape(
        functools.partial(api.init_params, cfg), jax.random.PRNGKey(0))


def _auto_grad_accum(cfg: ModelConfig, shape: ShapeConfig, mesh,
                     budget_bytes: float = 4e9) -> int:
    """Pick microbatch count so per-chip activation residuals fit the budget.

    Residual estimate: layer-scan carries (B_loc x S x d_model bf16 per
    layer) + fp32 logits (B_loc x S x vocab_shard) — the two dominant
    live-across-bwd tensors under full remat.
    """
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    dp = sizes.get("pod", 1) * sizes.get("data", 1)
    tp = sizes.get("model", 1)
    b_loc = max(shape.global_batch // dp, 1)
    tokens = b_loc * shape.seq_len
    resid = tokens * cfg.d_model * 2 * cfg.n_layers
    vshard = -(-cfg.vocab_size // tp)
    logits = tokens * vshard * 4 * 2          # logits + grad copy
    need = resid + logits
    accum = 1
    while need / accum > budget_bytes and accum < shape.global_batch // dp:
        accum *= 2
    return accum


def build_train_step(cfg: ModelConfig, shape: ShapeConfig, mesh,
                     tcfg: Optional[TrainConfig] = None,
                     master_weights: bool = False) -> BuiltStep:
    """Full training step: (accumulated) loss → grads → AdamW(+ZeRO-1)."""
    tcfg = tcfg or TrainConfig()
    sharder = make_sharder(cfg, mesh)
    accum = tcfg.grad_accum or _auto_grad_accum(cfg, shape, mesh)

    def train_step(state, batch):
        with use_sharder(sharder):
            params = state["params"]

            def loss_and_grads(mbatch):
                return jax.value_and_grad(
                    lambda p: api.loss_fn(p, mbatch, cfg))(params)

            if accum == 1:
                loss, grads = loss_and_grads(batch)
                grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
            else:
                mb = jax.tree.map(
                    lambda x: x.reshape((accum, x.shape[0] // accum)
                                        + x.shape[1:]), batch)

                def micro(gacc, mbatch):
                    l, g = loss_and_grads(mbatch)
                    gacc = jax.tree.map(
                        lambda a, b: a + b.astype(jnp.float32), gacc, g)
                    return gacc, l

                gacc0 = jax.tree.map(
                    lambda p: jnp.zeros(p.shape, jnp.float32), params)
                grads, losses = jax.lax.scan(micro, gacc0, mb)
                grads = jax.tree.map(lambda g: g / accum, grads)
                loss = losses.mean()
            new_params, new_opt, metrics = adamw_update(
                grads, state["opt"], params, tcfg)
            return ({"params": new_params, "opt": new_opt},
                    {"loss": loss, **metrics})

    params_shapes = _eval_params(cfg)
    pspecs = param_specs(params_shapes, cfg, sharder)
    opt_shapes = jax.eval_shape(
        functools.partial(init_opt_state, tcfg=tcfg, master=master_weights),
        params_shapes)
    mspecs = (zero1_specs(pspecs, params_shapes, sharder) if tcfg.zero1
              else pspecs)
    opt_specs = OptState(step=P(), m=mspecs, v=mspecs,
                         master=(mspecs if master_weights else None))
    bspecs = make_batch_specs(cfg, shape.global_batch,
                              shape.seq_len, kind="train")
    bshard = batch_specs_sharding(bspecs, sharder)

    state_specs = {"params": pspecs, "opt": opt_specs}
    state_shapes = {"params": params_shapes, "opt": opt_shapes}
    ns = lambda tree: jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), tree,
        is_leaf=lambda x: isinstance(x, P))
    in_shardings = (ns(state_specs), ns(bshard))
    out_shardings = (ns(state_specs),
                     {"loss": NamedSharding(mesh, P()),
                      "grad_norm": NamedSharding(mesh, P()),
                      "lr": NamedSharding(mesh, P())})
    return BuiltStep(train_step, in_shardings, out_shardings,
                     (state_shapes, bspecs), donate_argnums=(0,))


def build_prefill_step(cfg: ModelConfig, shape: ShapeConfig, mesh) -> BuiltStep:
    resolve_fabric(cfg, shape)
    sharder = make_sharder(cfg, mesh)
    t_max = shape.seq_len

    def prefill_step(params, batch):
        with use_sharder(sharder):
            logits, caches = api.prefill_fn(params, batch, cfg, t_max)
            return logits, caches

    params_shapes = _eval_params(cfg)
    pspecs = param_specs(params_shapes, cfg, sharder)
    if cfg.serve_fsdp:   # inference FSDP: stream weights over the data axis
        pspecs = zero1_specs(pspecs, params_shapes, sharder)
    bspecs = make_batch_specs(cfg, shape.global_batch, shape.seq_len,
                              kind="prefill")
    bshard = batch_specs_sharding(bspecs, sharder)
    cache_shapes = jax.eval_shape(
        functools.partial(api.init_cache, cfg, shape.global_batch, t_max))
    cspecs = cache_specs(cache_shapes, cfg, sharder)
    ns = lambda tree: jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), tree,
        is_leaf=lambda x: isinstance(x, P))
    logits_spec = sharder.safe_spec(
        (shape.global_batch, 1, cfg.vocab_size), ("batch", None, "vocab"))
    in_shardings = (ns(pspecs), ns(bshard))
    out_shardings = (NamedSharding(mesh, logits_spec), ns(cspecs))
    return BuiltStep(prefill_step, in_shardings, out_shardings,
                     (params_shapes, bspecs))


def build_decode_step(cfg: ModelConfig, shape: ShapeConfig, mesh) -> BuiltStep:
    """One decode step against a seq_len-deep KV cache (the serve_step that
    ``decode_*``/``long_*`` cells lower).  The cache is read through the
    model's fabric (``resolve_fabric`` checks the geometry up front).

    Under ``cfg.serve_fsdp`` the step runs burst-scheduled: the ZeRO-1
    weight re-gather traffic enqueues as ``weight_stream`` ports in the same
    read burst as the KV banking (one network invocation per dtype), so the
    per-step weight movement batches with KV traffic instead of issuing its
    own transfers."""
    fab = resolve_fabric(cfg, shape)
    sharder = make_sharder(cfg, mesh)
    t_max = shape.seq_len

    def serve_step(params, caches, token, pos):
        from repro.fabric import BurstScheduler, Fabric
        with use_sharder(sharder):
            sched = BurstScheduler(Fabric(fab)) if cfg.serve_fsdp else None
            logits, new_caches = api.decode_fn(params, token, caches, pos,
                                               cfg, sched=sched)
            return logits, new_caches

    params_shapes = _eval_params(cfg)
    pspecs = param_specs(params_shapes, cfg, sharder)
    if cfg.serve_fsdp:   # inference FSDP: stream weights over the data axis
        pspecs = zero1_specs(pspecs, params_shapes, sharder)
    cache_shapes = jax.eval_shape(
        functools.partial(api.init_cache, cfg, shape.global_batch, t_max))
    cspecs = cache_specs(cache_shapes, cfg, sharder)
    tok_spec = jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32)
    pos_spec = jax.ShapeDtypeStruct((), jnp.int32)
    ns = lambda tree: jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), tree,
        is_leaf=lambda x: isinstance(x, P))
    logits_spec = sharder.safe_spec(
        (shape.global_batch, 1, cfg.vocab_size), ("batch", None, "vocab"))
    in_shardings = (ns(pspecs), ns(cspecs),
                    NamedSharding(mesh, sharder.safe_spec((shape.global_batch, 1),
                                                          ("batch", None))),
                    NamedSharding(mesh, P()))
    out_shardings = (NamedSharding(mesh, logits_spec), ns(cspecs))
    return BuiltStep(serve_step, in_shardings, out_shardings,
                     (params_shapes, cache_shapes, tok_spec, pos_spec),
                     donate_argnums=(1,))


def build_step(cfg: ModelConfig, shape: ShapeConfig, mesh,
               tcfg: Optional[TrainConfig] = None) -> BuiltStep:
    """Dispatch on the shape kind (train / prefill / decode)."""
    if shape.kind == "train":
        return build_train_step(cfg, shape, mesh, tcfg)
    if shape.kind == "prefill":
        return build_prefill_step(cfg, shape, mesh)
    return build_decode_step(cfg, shape, mesh)
