"""Serving driver: ``python -m repro.launch.serve --arch <id> [...]``.

Batched request serving: prefill installs the line-major KV caches, the
decode loop reads them through the Medusa interconnect (``cfg.kv_layout``).
``--smoke`` runs the reduced config on CPU with real tokens.
"""

from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_smoke
from repro.data import SyntheticLM
from repro.models import api


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-12b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen-len", type=int, default=32)
    ap.add_argument("--kv-layout", default=None,
                    choices=[None, "medusa", "crossbar", "oracle"])
    args = ap.parse_args()

    cfg = get_smoke(args.arch) if args.smoke else get_config(args.arch)
    if args.kv_layout:
        cfg = dataclasses.replace(cfg, kv_layout=args.kv_layout)

    data = SyntheticLM(cfg, batch=args.batch,
                       seq=args.prompt_len + (cfg.n_patches or 0))
    batch = {k: jnp.asarray(v) for k, v in data.batch_at(0).items()}
    batch.pop("targets")
    params = api.init_params(cfg, jax.random.PRNGKey(0))

    t_max = args.prompt_len + args.gen_len + (cfg.n_patches or 0)
    t0 = time.time()
    extra = {k: batch[k] for k in ("patch_embeds", "frames") if k in batch}
    out = api.greedy_generate(params, batch["tokens"], cfg,
                              steps=args.gen_len, t_max=t_max, extra=extra)
    out = np.asarray(out)
    dt = time.time() - t0
    print(f"arch={cfg.name} kv_layout={cfg.kv_layout} "
          f"batch={args.batch} prompt={args.prompt_len} gen={args.gen_len}")
    print(f"generated {out.shape} in {dt:.2f}s "
          f"({args.batch * args.gen_len / dt:.1f} tok/s)")
    print("sample:", out[0][:16].tolist())


if __name__ == "__main__":
    main()
