"""Serving driver: ``python -m repro.launch.serve --arch <id> [...]``.

Batched request serving: prefill installs the line-major KV caches, the
decode loop reads them through the model's fabric (``cfg.resolved_fabric``;
override with ``--fabric-impl``).  ``--smoke`` runs the reduced config on
CPU with real tokens; ``--engine`` serves through the continuous-batching
:class:`repro.serving.ServingEngine` on the paged KV layout instead of the
one-shot batch generate — its decode step is burst-scheduled (one read +
one write network invocation per dtype per step; ``--pack`` selects the
burst layout, ``--word-fold`` the machine-word lane folding cap,
``--serve-fsdp`` adds the weight stream to the read burst).  KV storage
defaults to the shared physical page pool (``--paged-pool`` /
``--no-paged-pool``, ``--pool-pages`` sizes it): gather-based decode
through the per-slot page table, admission installed as ``prefill/*``
write-burst traffic, retirement reclaims pages.  Under oversubscription the
engine degrades gracefully instead of stalling: ``--priority-classes``
spreads the synthetic load over priority classes, ``--preempt
{swap,recompute,off}`` picks the victim policy (page-level swap over the
fabric's ``swap/*`` streams, or drop + re-prefill), ``--swap-space-pages``
caps the host swap space, and ``--check-pool`` runs the free-list
conservation invariant every step.  ``--aging`` turns on anti-starvation
aging (queued wait boosts effective priority) and ``--max-queue`` bounds
the submit queue with shed-on-overflow backpressure; for production-shaped
traffic with deadlines and per-class latency percentiles use
``python -m repro.launch.loadgen``.  On the medusa fabric with kernels
enabled each burst lowers as one fused Pallas launch.
"""

from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_smoke
from repro.data import SyntheticLM
from repro.models import api


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-12b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen-len", type=int, default=32)
    ap.add_argument("--kv-layout", "--fabric-impl", dest="kv_layout",
                    default=None,
                    choices=[None, "medusa", "crossbar", "oracle", "fused"])
    ap.add_argument("--page-size", type=int, default=0,
                    help="KV page size in timesteps (0 = fabric default)")
    ap.add_argument("--paged-pool", action=argparse.BooleanOptionalAction,
                    default=None,
                    help="back the engine's full-attention KV in one shared "
                         "physical page pool with gather-based decode "
                         "(default: FabricConfig.paged_pool, on); "
                         "--no-paged-pool keeps the dense per-slot "
                         "reservation (the A/B baseline)")
    ap.add_argument("--pool-pages", type=int, default=0,
                    help="physical pages in the shared pool (0 = the dense "
                         "reservation's worth: max_slots * pages_per_slot)")
    ap.add_argument("--fused-gather", action=argparse.BooleanOptionalAction,
                    default=None,
                    help="fuse the pool's logical->physical gather into the "
                         "burst contract: the networks move only the frames "
                         "the page table maps (default: FabricConfig."
                         "fused_gather, auto-on with the pool); "
                         "--no-fused-gather keeps the gather-after-burst "
                         "fallback that banks the whole pool")
    ap.add_argument("--engine", action="store_true",
                    help="serve through the paged continuous-batching engine")
    ap.add_argument("--pool-shards", type=int, default=0,
                    help="shard the physical page pool over this many "
                         "devices on a `pool` mesh axis: fused sparse "
                         "bursts lower as per-shard gathers bridged by one "
                         "collective, pages stripe round-robin across "
                         "shards (0 = FabricConfig.pool_shards, off); "
                         "needs XLA_FLAGS=--xla_force_host_platform_"
                         "device_count=<shards> on CPU")
    ap.add_argument("--collective", default=None,
                    choices=[None, "all_to_all", "ring"],
                    help="exchange-hop collective for the sharded pool: "
                         "XLA's all_to_all or the explicit ring of "
                         "ppermute rotations (the butterfly-vs-rotation "
                         "A/B; value-identical)")
    ap.add_argument("--pack", default=None, choices=[None, "packed", "pad"],
                    help="burst layout for the scheduled decode step")
    ap.add_argument("--word-fold", default=None,
                    choices=[None, "auto", "1", "2", "4"],
                    help="machine-word lane folding cap for packed bursts "
                         "(auto = widest the dtype/geometry/x64 allow)")
    ap.add_argument("--serve-fsdp", action="store_true",
                    help="stream ZeRO-1 sharded weights through the decode "
                         "step's read burst (weight_stream ports)")
    ap.add_argument("--priority-classes", type=int, default=1,
                    help="spread the synthetic requests over this many "
                         "priority classes (request i gets priority "
                         "i %% P); higher classes preempt lower when the "
                         "pool is oversubscribed")
    ap.add_argument("--preempt", default=None,
                    choices=[None, "swap", "recompute", "off"],
                    help="victim policy when a higher-priority request "
                         "would otherwise wait: swap pages to host over "
                         "the fabric (swap/* streams), drop + re-prefill, "
                         "or off = the head-of-line gate (default: "
                         "FabricConfig.preempt)")
    ap.add_argument("--swap-space-pages", type=int, default=None,
                    help="host swap-space cap in pages; evictions beyond "
                         "it fall back to recompute (default: FabricConfig."
                         "swap_space_pages, 0 = unbounded)")
    ap.add_argument("--check-pool", action="store_true",
                    help="run the pool's free-list conservation invariant "
                         "after every engine step (debug)")
    ap.add_argument("--aging", type=int, default=0,
                    help="anti-starvation aging quantum: each this-many "
                         "steps a queued request waits boosts its "
                         "effective priority one class, in admission rank "
                         "and preemption eligibility both (0 = strict "
                         "priority order, low classes can starve)")
    ap.add_argument("--max-queue", type=int, default=0,
                    help="bounded submit queue: submits beyond this depth "
                         "are shed with backpressure "
                         "(SchedulerStats.shed_queue_full; 0 = unbounded)")
    ap.add_argument("--spec-decode-k", type=int, default=0,
                    help="Medusa-heads speculative decoding: k draft heads "
                         "propose a candidate branch per slot each step and "
                         "the engine's verify_step accepts its longest "
                         "matching prefix against the committed argmax "
                         "(token stream identical to k=0; the census "
                         "reports the acceptance rate)")
    args = ap.parse_args()

    cfg = get_smoke(args.arch) if args.smoke else get_config(args.arch)
    if args.kv_layout:
        cfg = dataclasses.replace(cfg, kv_layout=args.kv_layout)
        if cfg.fabric is not None:   # explicit fabric: keep the switch single
            cfg = dataclasses.replace(
                cfg, fabric=dataclasses.replace(cfg.fabric,
                                                impl=args.kv_layout))
    if args.page_size:
        cfg = dataclasses.replace(
            cfg, fabric=dataclasses.replace(cfg.resolved_fabric,
                                            page_size=args.page_size))
    if args.pack:
        cfg = dataclasses.replace(
            cfg, fabric=dataclasses.replace(cfg.resolved_fabric,
                                            pack=args.pack))
    if args.word_fold:
        fold = "auto" if args.word_fold == "auto" else int(args.word_fold)
        cfg = dataclasses.replace(
            cfg, fabric=dataclasses.replace(cfg.resolved_fabric,
                                            word_fold=fold))
    if args.serve_fsdp:
        cfg = dataclasses.replace(cfg, serve_fsdp=True)
    if args.paged_pool is not None:
        cfg = dataclasses.replace(
            cfg, fabric=dataclasses.replace(cfg.resolved_fabric,
                                            paged_pool=args.paged_pool))
    if args.fused_gather is not None:
        cfg = dataclasses.replace(
            cfg, fabric=dataclasses.replace(cfg.resolved_fabric,
                                            fused_gather=args.fused_gather))
    if args.spec_decode_k:
        # draft heads are model params: init_params grows the "draft" entry
        cfg = dataclasses.replace(cfg, spec_heads=args.spec_decode_k)
    fab = cfg.resolved_fabric

    data = SyntheticLM(cfg, batch=args.batch,
                       seq=args.prompt_len + (cfg.n_patches or 0))
    batch = {k: jnp.asarray(v) for k, v in data.batch_at(0).items()}
    batch.pop("targets")
    params = api.init_params(cfg, jax.random.PRNGKey(0))

    t_max = args.prompt_len + args.gen_len + (cfg.n_patches or 0)
    print(f"arch={cfg.name} fabric=[impl={fab.impl} N={fab.n_ports} "
          f"W_acc={fab.lane_width} page={fab.page_size} pack={fab.pack} "
          f"fold={fab.word_fold}] "
          f"batch={args.batch} prompt={args.prompt_len} gen={args.gen_len}")
    t0 = time.time()
    if args.engine:
        from repro.serving import Request, ServingEngine
        eng = ServingEngine(cfg, params, max_slots=args.batch, t_max=t_max,
                            pool_pages=args.pool_pages,
                            pool_shards=args.pool_shards,
                            collective=args.collective,
                            preempt=args.preempt,
                            swap_space_pages=args.swap_space_pages,
                            check_pool=args.check_pool,
                            spec_decode_k=args.spec_decode_k,
                            aging=args.aging, max_queue=args.max_queue)
        prompts = np.asarray(batch["tokens"])
        reqs = [Request(i, prompts[i], max_new_tokens=args.gen_len,
                        priority=i % max(args.priority_classes, 1))
                for i in range(args.batch)]
        for r in reqs:
            eng.submit(r)
        eng.run_to_completion()
        dt = time.time() - t0
        kv = eng.kv
        print(f"served {args.batch} requests in {dt:.2f}s "
              f"({args.batch * args.gen_len / dt:.1f} tok/s); "
              f"admission moved {kv.tokens_moved} of "
              f"{kv.tokens_moved_dense} dense-splice timesteps")
        if kv.paged:
            pool = kv.pool
            print(f"page pool: {pool.n_pages} physical pages x "
                  f"{pool.page_size} timesteps "
                  f"(dense reservation {kv.dense_reserved_pages} pages); "
                  f"{pool.pages_allocated} allocated, "
                  f"{pool.pages_reclaimed} reclaimed, "
                  f"{pool.pages_in_use} in use at exit; "
                  f"{kv.prefill_bursts} prefill write bursts, "
                  f"{kv.prefill_splices} splice fallbacks")
            fs = eng.fabric_stats
            print(f"preemption[{eng.preempt}]: {fs.preemptions} "
                  f"preemptions; swap {pool.pages_swapped_out} pages out / "
                  f"{pool.pages_swapped_in} back "
                  f"({fs.swap_out_words} words out, {fs.swap_in_words} in "
                  f"over {fs.swap_bursts} swap bursts); "
                  f"{fs.bursts_retried} bursts retried, "
                  f"{fs.faults_recovered} faults recovered")
            print(f"admission: {fs.requests_shed} shed "
                  f"({fs.shed_queue_full} queue-full, "
                  f"{fs.shed_deadline} unmeetable-deadline); "
                  f"SLO misses {fs.slo_missed_served} served late + "
                  f"{fs.slo_missed_shed} shed; "
                  f"{fs.aging_promotions} aging promotions")
        else:
            print("page pool: off (dense per-slot reservation)")
        fs = eng.fabric_stats
        if fs.flushes:
            print(f"fabric per step: {fs.network_calls} network calls for "
                  f"{fs.streams_served} streams over {fs.flushes} bursts "
                  f"({fs.words_moved} words moved, {fs.words_padded} padded, "
                  f"{fs.words_folded} folded into machine words, "
                  f"{fs.kernel_bursts} fused-kernel bursts, "
                  f"{fs.prefill_bursts} prefill bursts)")
            if fs.gather_fused_bursts:
                print(f"fused gather: {fs.words_live} live-frame words "
                      f"through {fs.gather_fused_bursts} sparse-extent "
                      f"bursts (decode traffic scales with live tokens, "
                      f"not pool capacity)")
                if fs.collective_calls:
                    local = fs.words_moved - fs.words_cross_shard
                    print(f"sharded pool: {eng.pool_shards} shards x "
                          f"{eng.fabric.config.collective} — "
                          f"{fs.words_cross_shard} words crossed shards vs "
                          f"{max(local, 0)} local, through "
                          f"{fs.collective_calls} collective exchanges "
                          f"(pages striped "
                          f"{eng.kv.pool.free_pages_by_shard} free/shard)")
            elif eng.paged:
                print("fused gather: off — gather-after-burst fallback "
                      "banks the whole pool each step")
        else:
            print("fabric: decode step unscheduled (geometry fallback)")
        if cfg.moe is not None:
            print(f"moe dispatch: {fs.tokens_dropped} token assignments "
                  f"dropped at capacity over the whole run (sentinel rows "
                  f"in the dispatch scatter; residual passed through)")
        if eng.spec_k:
            print(f"speculative decode[k={eng.spec_k}]: "
                  f"{eng.spec_accepted}/{eng.spec_proposed} draft tokens "
                  f"accepted ({eng.spec_acceptance:.1%}), "
                  f"{eng.spec_rejected} rejected; per-step gathered-branch "
                  f"words {fs.words_live} (the k candidate branches share "
                  f"the committed prefix, so the fused page-table gather "
                  f"serves all of them)")
        print("sample:", reqs[0].generated[:16])
    else:
        extra = {k: batch[k] for k in ("patch_embeds", "frames") if k in batch}
        out = api.greedy_generate(params, batch["tokens"], cfg,
                                  steps=args.gen_len, t_max=t_max, extra=extra)
        out = np.asarray(out)
        dt = time.time() - t0
        print(f"generated {out.shape} in {dt:.2f}s "
              f"({args.batch * args.gen_len / dt:.1f} tok/s)")
        print("sample:", out[0][:16].tolist())


if __name__ == "__main__":
    main()
