"""Trip-count-corrected HLO cost model for the roofline analysis.

``compiled.cost_analysis()`` counts a ``while`` body ONCE (verified in this
environment: an 8-step scan of a 128³ matmul reports 2·128³ flops, not 8×).
Since every model here scans over layers, we parse ``compiled.as_text()``
ourselves:

* computations are walked from ENTRY; a ``while`` body's costs are multiplied
  by its trip count, recovered from the loop condition's ``compare`` against a
  constant (scan always lowers to that form); nesting multiplies.
* FLOPs: ``dot`` ops contribute ``2 x prod(result_dims) x prod(contracted)``
  (contracted dims parsed from ``lhs_contracting_dims``); ``dot`` inside
  fusion bodies is charged at the call-site multiplier.
* HBM bytes: fusion boundaries are materialisation boundaries, so every
  top-level op (excluding parameter/constant/tuple plumbing/bitcast)
  contributes operand+result bytes x multiplier.
* collective bytes: for all-gather / all-reduce / reduce-scatter / all-to-all
  / collective-permute ops, the payload is ``max(operand bytes, result
  bytes)`` x multiplier (ring-algorithm factors are NOT applied — documented
  choice; the roofline divides by one link's bandwidth as the conservative
  single-link model).

The same parse records the collective op census (op kind → count, bytes) used
by EXPERIMENTS.md §Dry-run and the interconnect benchmarks.
"""

from __future__ import annotations

import dataclasses
import math
import re
from collections import defaultdict
from typing import Dict, Optional

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8, "f8e4m3fn": 1, "f8e5m2": 1,
    "bf16": 2, "f16": 2, "f32": 4, "f64": 8, "c64": 8, "c128": 16,
    "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
_CALLS_RE = re.compile(r"calls=%?([\w\.\-]+)")
_WHILE_RE = re.compile(r"condition=%?([\w\.\-]+), body=%?([\w\.\-]+)")
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_TO_APPLY_RE = re.compile(r"to_apply=%?([\w\.\-]+)")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')

COLLECTIVE_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                  "collective-permute")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def _line_shapes(segment: str):
    return [(m.group(1), m.group(2)) for m in _SHAPE_RE.finditer(segment)]


@dataclasses.dataclass
class HloCosts:
    flops: float = 0.0
    bytes: float = 0.0
    collective_bytes: float = 0.0
    collective_census: Dict[str, list] = dataclasses.field(
        default_factory=lambda: defaultdict(lambda: [0, 0.0]))
    while_trip_counts: Dict[str, int] = dataclasses.field(default_factory=dict)

    def as_dict(self) -> dict:
        return {
            "flops": self.flops,
            "bytes": self.bytes,
            "collective_bytes": self.collective_bytes,
            "collectives": {k: {"count": v[0], "bytes": v[1]}
                            for k, v in self.collective_census.items()},
            "while_trip_counts": self.while_trip_counts,
        }


def parse_computations(hlo_text: str) -> Dict[str, list]:
    comps: Dict[str, list] = {}
    cur = None
    entry = None
    for line in hlo_text.splitlines():
        stripped = line.strip()
        rline = line.rstrip()
        # computation header: [ENTRY] %name (args...) -> type {
        if rline.endswith("{") and "->" in rline and not line.startswith(" "):
            head = rline.lstrip()
            is_entry = head.startswith("ENTRY")
            if is_entry:
                head = head[len("ENTRY"):].lstrip()
            name = head.split("(", 1)[0].strip().lstrip("%").strip()
            if name:
                cur = name
                comps[cur] = []
                if is_entry:
                    entry = cur
            continue
        if stripped == "}":
            cur = None
            continue
        if cur is not None and "=" in stripped and stripped.startswith(("%", "ROOT")):
            comps[cur].append(stripped)
    comps["__entry__"] = [entry]
    return comps


def _trip_count(cond_lines: list) -> int:
    """Recover the scan trip count from the loop condition: a compare of the
    induction variable against a constant."""
    consts = {}
    for ln in cond_lines:
        m = re.match(r"%?([\w\.\-]+)\s*=\s*\w+\[\]\s*constant\((\-?\d+)\)", ln)
        if m:
            consts[m.group(1)] = int(m.group(2))
    for ln in cond_lines:
        if " compare(" not in ln:
            continue
        m = re.search(r"compare\(([^)]*)\)", ln)
        args = [a.strip().lstrip("%").split(" ")[-1].lstrip("%")
                for a in m.group(1).split(",")] if m else []
        dirn = re.search(r"direction=(\w+)", ln)
        for a in args:
            if a in consts:
                c = consts[a]
                if dirn and dirn.group(1) == "LT":
                    return max(c, 1)
                if dirn and dirn.group(1) in ("LE",):
                    return max(c + 1, 1)
                return max(c, 1)
        # compare against inline constant: compare(%x, s32[] constant(8))
        m2 = re.search(r"constant\((\d+)\)", ln)
        if m2:
            return max(int(m2.group(1)), 1)
    return 1


_NAME_RE = re.compile(r"^(?:ROOT\s+)?%?([\w\.\-]+)\s*=")
_OPERAND_RE = re.compile(r"%([\w\.\-]+)")


def _result_name(line: str) -> Optional[str]:
    m = _NAME_RE.match(line)
    return m.group(1) if m else None


_OPCODE_RE = re.compile(r"\b([a-z][\w\-]*)\(")


def _split_op(line: str):
    """→ (result-type region, opcode, operand region) of an HLO op line.
    The opcode is the first lowercase word directly followed by '(' — type
    tokens use brackets, tuple-result parens are not word-adjacent."""
    rhs = line.split("=", 1)[1]
    m = _OPCODE_RE.search(rhs)
    if not m:
        return rhs, "", ""
    operands = rhs[m.end():].split(")", 1)[0]
    return rhs[: m.start()], m.group(1), operands


def _result_shapes(line: str):
    """Shape tokens of the op result (the typed region before the opcode)."""
    res, _, _ = _split_op(line)
    return _line_shapes(res)


def _operand_names(line: str):
    _, _, operands = _split_op(line)
    return _OPERAND_RE.findall(operands)


def _symtab(lines) -> Dict[str, int]:
    """name → result bytes, from each op's typed result."""
    tab: Dict[str, int] = {}
    for ln in lines:
        name = _result_name(ln)
        if name:
            tab[name] = sum(_shape_bytes(dt, dims)
                            for dt, dims in _result_shapes(ln))
    return tab


def _symtab_dims(lines) -> Dict[str, list]:
    """name → result dims (first shape token only), for dot contraction."""
    tab: Dict[str, list] = {}
    for ln in lines:
        name = _result_name(ln)
        if name:
            shapes = _result_shapes(ln)
            if shapes:
                tab[name] = [int(d) for d in shapes[0][1].split(",") if d]
    return tab


def _dot_flops(line: str, dims_tab: Dict[str, list]) -> float:
    """2 x prod(result) x prod(contracted dims of lhs)."""
    shapes = _result_shapes(line)
    if not shapes:
        return 0.0
    res = 1
    for d in shapes[0][1].split(","):
        if d:
            res *= int(d)
    operands = _operand_names(line)
    lhs_dims = dims_tab.get(operands[0], []) if operands else []
    m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", line)
    contracted = 1
    if m and m.group(1):
        for idx in m.group(1).split(","):
            i = int(idx)
            if i < len(lhs_dims):
                contracted *= lhs_dims[i]
    return 2.0 * res * contracted


def _op_bytes(line: str, tab: Dict[str, int]) -> float:
    """HBM traffic model for one op.

    Slicing ops read/write only the touched REGION, not their full operands
    — charging operands in full would overcount a layer-scan (which
    dynamic-slices one layer per iteration from the stacked params/cache) by
    the layer count, quadratically.  In-place dynamic-update-slice (donated
    buffers alias) moves 2x the update region.
    """
    res, op, operands = _split_op(line)
    res_b = sum(_shape_bytes(dt, dims) for dt, dims in _line_shapes(res))
    names = _OPERAND_RE.findall(operands)
    if op in ("dynamic-slice", "slice", "gather"):
        return float(2 * res_b)
    if op == "dynamic-update-slice":
        upd = tab.get(names[1], 0) if len(names) > 1 else 0
        return float(2 * upd)
    if op == "scatter":
        upd = tab.get(names[2], 0) if len(names) > 2 else res_b
        return float(2 * upd)
    ops_b = sum(tab.get(n, 0) for n in names)
    return float(res_b + ops_b)


def _fusion_bytes(line: str, tab: Dict[str, int], fused_lines: list,
                  fused_tab: Dict[str, int]) -> float:
    """Traffic of a fusion op, aware of in-place roots.

    A fusion whose root is a dynamic-update-slice writes into an ALIASED
    buffer (XLA aliases scan-carry updates): the traffic is the update region
    (2x: read update + write region), not the whole buffer — charging the
    full stacked KV cache per layer-scan iteration would overcount by the
    layer count.  Other fusions move operands in + result out.
    """
    root = None
    ops_in_body = []
    for fl in fused_lines:
        _, fop, _ = _split_op(fl)
        if fop and fop not in ("parameter", "bitcast", "constant"):
            ops_in_body.append(fop)
        if fl.startswith("ROOT"):
            root = fl
    # pure dtype-cast fusion: CPU-only artifact (no bf16 GEMM on host — XLA
    # shadows the cache in f32); on the TPU target the MXU consumes bf16
    # directly and these converts do not exist.  Charged zero (documented).
    if ops_in_body and all(o == "convert" for o in ops_in_body):
        return 0.0
    if root is not None:
        _, root_op, _ = _split_op(root)
        if root_op in ("dynamic-update-slice", "convert"):
            # in-place update (aliased buffer), possibly convert-wrapped:
            # traffic = the update region, not the whole buffer
            for fl in fused_lines:
                _, fop, _ = _split_op(fl)
                if fop == "dynamic-update-slice":
                    names = _operand_names(fl)
                    upd = fused_tab.get(names[1], 0) if len(names) > 1 else 0
                    if upd:
                        return float(2 * upd)
    # generic fusion: result + operands, but an operand that the body only
    # SLICES is charged at the sliced-region size (a layer scan reads one
    # layer of a stacked parameter per iteration, not the whole stack).
    res_b = sum(_shape_bytes(dt, dims) for dt, dims in _result_shapes(line))
    names = _operand_names(line)
    param_charge = {}
    for fl in fused_lines:
        _, fop, _ = _split_op(fl)
        if fop in ("dynamic-slice", "slice", "gather"):
            ops_in = _operand_names(fl)
            if ops_in and ops_in[0].startswith("param_"):
                try:
                    pi = int(ops_in[0].split("_")[1].split(".")[0])
                except ValueError:
                    continue
                sliced = sum(_shape_bytes(dt, dims)
                             for dt, dims in _result_shapes(fl))
                param_charge[pi] = min(param_charge.get(pi, sliced), sliced)
    total = float(res_b)
    for i, n in enumerate(names):
        total += param_charge.get(i, tab.get(n, 0))
    return total


def _collective_payload(line: str, tab: Dict[str, int]) -> float:
    res = sum(_shape_bytes(dt, dims) for dt, dims in _result_shapes(line))
    ops = sum(tab.get(n, 0) for n in _operand_names(line))
    return float(max(res, ops))


_SKIP_OPS = ("parameter(", "constant(", "tuple(", "get-tuple-element(",
             "bitcast(", "after-all(", "partition-id(", "replica-id(",
             "iota(")


def analyze_hlo(hlo_text: str) -> HloCosts:
    comps = parse_computations(hlo_text)
    entry = comps.pop("__entry__")[0]
    costs = HloCosts()
    symtabs = {name: _symtab(lines) for name, lines in comps.items()}
    dimstabs = {name: _symtab_dims(lines) for name, lines in comps.items()}

    def opcode_of(ln: str) -> str:
        _, op, _ = _split_op(ln)
        return op

    def walk(name: str, mult: float, flops_only: bool = False):
        tab = symtabs.get(name, {})
        dtab = dimstabs.get(name, {})
        for ln in comps.get(name, ()):  # pragma: no branch
            rhs = ln.split("=", 1)[1]
            op = opcode_of(ln)
            if op == "while":
                m = _WHILE_RE.search(ln)
                if m:
                    mt = _TRIP_RE.search(ln)
                    trips = (int(mt.group(1)) if mt
                             else _trip_count(comps.get(m.group(1), [])))
                    costs.while_trip_counts[m.group(2)] = trips
                    walk(m.group(2), mult * trips, flops_only)
                continue
            if op == "conditional":
                mb = _BRANCHES_RE.search(ln)
                if mb:
                    for b in mb.group(1).split(","):
                        walk(b.strip().lstrip("%"), mult, flops_only)
                continue
            if op in ("call", "async-start"):
                mc = _TO_APPLY_RE.search(ln) or _CALLS_RE.search(ln)
                if mc:
                    walk(mc.group(1), mult, flops_only)
            if op == "fusion":
                mc = _CALLS_RE.search(ln)
                if mc:
                    walk(mc.group(1), mult, flops_only=True)
                if not flops_only:
                    costs.bytes += _fusion_bytes(
                        ln, tab, comps.get(mc.group(1), []) if mc else [],
                        symtabs.get(mc.group(1), {}) if mc else {}) * mult
                continue
            if op == "dot":
                costs.flops += _dot_flops(ln, dtab) * mult
            coll = next((c for c in COLLECTIVE_OPS
                         if f" {c}(" in rhs or f" {c}-start(" in rhs), None)
            if coll and not flops_only:
                payload = _collective_payload(ln, tab) * mult
                costs.collective_bytes += payload
                costs.collective_census[coll][0] += int(mult)
                costs.collective_census[coll][1] += payload
                costs.bytes += _op_bytes(ln, tab) * mult
                continue
            if flops_only:
                continue
            if any(s in rhs for s in _SKIP_OPS):
                continue
            costs.bytes += _op_bytes(ln, tab) * mult

    if entry:
        walk(entry, 1.0)
    return costs


# ---------------------------------------------------------------------------
# analytic model FLOPs (the "useful work" reference for §Roofline)
# ---------------------------------------------------------------------------

def model_flops(cfg, shape) -> float:
    """6·N·D for training (N = active params, D = tokens); 2·N·D for
    forward-only (prefill); 2·N·B per decode step."""
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    return 2.0 * n_active * shape.global_batch    # one decode step


def roofline_terms(costs: HloCosts, chips: int,
                   peak_flops: float = 197e12, hbm_bw: float = 819e9,
                   link_bw: float = 50e9) -> dict:
    """Three roofline terms in seconds (costs are per-device: the compiled
    module is the post-partitioning per-device program)."""
    compute_s = costs.flops / peak_flops
    memory_s = costs.bytes / hbm_bw
    collective_s = costs.collective_bytes / link_bw
    dominant = max((("compute", compute_s), ("memory", memory_s),
                    ("collective", collective_s)), key=lambda kv: kv[1])[0]
    return {
        "compute_s": compute_s,
        "memory_s": memory_s,
        "collective_s": collective_s,
        "dominant": dominant,
        "bound_s": max(compute_s, memory_s, collective_s),
    }
