"""Production-shaped load generator: ``python -m repro.launch.loadgen``.

Replays a seeded traffic trace (:mod:`repro.serving.traffic` — Poisson or
bursty-diurnal arrivals, heavy-tailed lognormal prompt/generation lengths,
a weighted priority-class mix, an SLO-deadline mix) against the
continuous-batching engine, and reports per-class TTFT / TPOT / queue-wait
percentiles, goodput and the shed/SLO census alongside the fabric's
``SchedulerStats``.

``--replicas N`` serves the same trace through an in-process N-replica
fleet behind a least-loaded router (the single-host step toward the
ROADMAP k8s fleet).  ``--soak`` runs the trace twice — fault-free, then
under a seeded :class:`~repro.runtime.fault_tolerance.FaultInjector`
(mid-step failures, pool exhaustion, corrupted swap bursts) — and asserts
the two runs converge token-exact with zero page leaks (``PagePool.check``
at drain); a soak failure exits non-zero, so the nightly CI lane gates on
it.  Every run appends a record to ``BENCH_serving.json`` (same
append-only trajectory conventions as ``BENCH_fabric.json``; ``--no-bench``
skips), and ``--trace-out``/``--trace-in`` round-trip the trace itself for
bit-exact replay across machines.
"""

from __future__ import annotations

import argparse
import dataclasses
import datetime
import json
import os
import socket
import subprocess
import sys
import time

import jax

from repro.configs import get_config, get_smoke
from repro.models import api
from repro.runtime.fault_tolerance import FaultInjector
from repro.serving import (MetricsRecorder, ReplicaRouter, ServingEngine,
                           TrafficConfig, drive, fault_soak, generate_trace,
                           load_trace, save_trace, trace_t_max)


def _git_sha() -> str:
    try:
        return subprocess.check_output(
            ["git", "rev-parse", "--short", "HEAD"],
            cwd=os.path.dirname(os.path.dirname(os.path.dirname(
                os.path.dirname(os.path.abspath(__file__))))),
            stderr=subprocess.DEVNULL).decode().strip()
    except Exception:
        return "unknown"


def _append_run(path: str, run: dict) -> None:
    """Append-only trajectory, same conventions as ``BENCH_fabric.json``:
    keep every prior run record, never overwrite an unreadable file."""
    history = []
    if os.path.exists(path):
        try:
            with open(path) as f:
                old = json.load(f)
        except (OSError, json.JSONDecodeError):
            old = None
        if isinstance(old, dict) and isinstance(old.get("runs"), list):
            history = old["runs"]
        elif old is not None:
            aside = path + ".corrupt"
            os.replace(path, aside)
            print(f"# warning: {path} was not a recognized trajectory; "
                  f"moved to {aside}")
    history.append(run)
    with open(path, "w") as f:
        json.dump({"runs": history}, f, indent=2, sort_keys=True)


def _census(stats: dict) -> dict:
    """The SchedulerStats fields the serving trajectory tracks."""
    keys = ("preemptions", "swap_bursts", "bursts_retried",
            "faults_recovered", "requests_shed", "shed_queue_full",
            "shed_deadline", "slo_missed_served", "slo_missed_shed",
            "aging_promotions", "prefill_bursts", "network_calls",
            "words_moved", "words_live")
    return {k: stats.get(k, 0) for k in keys}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="starcoder2-15b")
    ap.add_argument("--smoke", action="store_true")
    # traffic shape
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--arrival", default="poisson",
                    choices=["poisson", "diurnal"])
    ap.add_argument("--rate", type=float, default=0.5,
                    help="mean arrivals per engine step")
    ap.add_argument("--prompt-mean", type=float, default=10.0)
    ap.add_argument("--prompt-max", type=int, default=24)
    ap.add_argument("--gen-mean", type=float, default=8.0)
    ap.add_argument("--gen-max", type=int, default=16)
    ap.add_argument("--classes", type=int, default=3,
                    help="priority classes (weighted toward class 0)")
    ap.add_argument("--deadline-frac", type=float, default=0.0,
                    help="fraction of requests carrying an SLO deadline")
    ap.add_argument("--deadline-slack", type=float, default=3.0,
                    help="deadline = arrival + slack * (gen_len + 2); "
                         "< 1.0 is provably unmeetable (born shed)")
    ap.add_argument("--trace-in", default=None,
                    help="replay a saved trace instead of generating one")
    ap.add_argument("--trace-out", default=None,
                    help="save the generated trace for bit-exact replay")
    # engine shape
    ap.add_argument("--max-slots", type=int, default=2)
    ap.add_argument("--page-size", type=int, default=4)
    ap.add_argument("--pool-pages", type=int, default=0,
                    help="0 = the dense reservation's worth; size it below "
                         "demand to exercise oversubscription")
    ap.add_argument("--preempt", default=None,
                    choices=[None, "swap", "recompute", "off"])
    ap.add_argument("--swap-space-pages", type=int, default=None)
    ap.add_argument("--aging", type=int, default=0,
                    help="anti-starvation aging quantum: queued wait boosts "
                         "effective priority one class per this many steps "
                         "(0 = strict priority order)")
    ap.add_argument("--max-queue", type=int, default=0,
                    help="bounded submit queue: overflow sheds with "
                         "backpressure (0 = unbounded)")
    ap.add_argument("--replicas", type=int, default=1,
                    help="serve through an in-process N-replica fleet "
                         "behind a least-loaded router")
    ap.add_argument("--check-pool", action="store_true", default=True)
    ap.add_argument("--max-steps", type=int, default=10_000)
    # fault soak
    ap.add_argument("--soak", action="store_true",
                    help="run the trace fault-free AND fault-injected, "
                         "asserting token-exact convergence + zero page "
                         "leaks at drain")
    ap.add_argument("--soak-p-fail", type=float, default=0.02)
    ap.add_argument("--soak-p-exhaust", type=float, default=0.05)
    ap.add_argument("--soak-corrupt", type=int, default=1)
    # trajectory
    ap.add_argument("--bench-out", default="BENCH_serving.json")
    ap.add_argument("--no-bench", action="store_true")
    args = ap.parse_args()

    cfg = get_smoke(args.arch) if args.smoke else get_config(args.arch)
    tcfg = TrafficConfig(
        seed=args.seed, n_requests=args.requests, arrival=args.arrival,
        rate=args.rate, prompt_mean=args.prompt_mean,
        prompt_max=args.prompt_max, gen_mean=args.gen_mean,
        gen_max=args.gen_max, classes=args.classes,
        deadline_frac=args.deadline_frac,
        deadline_slack=args.deadline_slack, vocab=cfg.vocab_size)
    if args.trace_in:
        trace = load_trace(args.trace_in)
    else:
        trace = generate_trace(tcfg)
    if args.trace_out:
        save_trace(args.trace_out, trace)
    t_max = trace_t_max(trace)
    params = api.init_params(cfg, jax.random.PRNGKey(0))

    def make_engine(fault_injector=None):
        def one(inj):
            return ServingEngine(
                cfg, params, max_slots=args.max_slots, t_max=t_max,
                page_size=args.page_size, pool_pages=args.pool_pages,
                preempt=args.preempt,
                swap_space_pages=args.swap_space_pages,
                check_pool=args.check_pool, fault_injector=inj,
                aging=args.aging, max_queue=args.max_queue)
        if args.replicas > 1:
            # one injector instance drives the whole fleet — fault ordinals
            # interleave deterministically because step() is lockstep
            return ReplicaRouter([one(fault_injector)
                                  for _ in range(args.replicas)])
        return one(fault_injector)

    n_dead = sum(t.deadline is not None for t in trace)
    print(f"arch={cfg.name} trace: {len(trace)} requests over "
          f"{max(t.arrival_step for t in trace) + 1} arrival steps "
          f"({args.arrival}, rate {args.rate}), {args.classes} classes, "
          f"{n_dead} deadlined; t_max={t_max}, "
          f"{args.replicas} replica(s), aging={args.aging}, "
          f"max_queue={args.max_queue or 'unbounded'}")

    t0 = time.time()
    if args.soak:
        horizon = args.max_steps
        inj = FaultInjector.seeded(args.seed, min(horizon, 4096),
                                   p_fail=args.soak_p_fail,
                                   p_exhaust=args.soak_p_exhaust,
                                   n_corrupt=args.soak_corrupt)
        ref_rec, rec, target = fault_soak(make_engine, trace, inj,
                                          max_steps=args.max_steps)
        mode = "soak"
        print(f"fault soak: token-exact vs fault-free run, zero page "
              f"leaks at drain (pool.check clean)")
    else:
        target = make_engine()
        rec = drive(target, trace, max_steps=args.max_steps)
        mode = "drive"
    dt = time.time() - t0

    stats = (target.stats() if isinstance(target, ReplicaRouter)
             else {f.name: getattr(target.fabric_stats, f.name)
                   for f in dataclasses.fields(target.fabric_stats)})
    report = rec.report()
    agg = report["aggregate"]
    print(rec.format_table())
    print(f"served {agg['served']}/{agg['n']} requests "
          f"({agg['tokens']} tokens) in {dt:.2f}s; "
          f"{agg['shed']} shed ({stats['shed_queue_full']} queue-full, "
          f"{stats['shed_deadline']} unmeetable-deadline); "
          f"SLO misses {stats['slo_missed_served']} served late + "
          f"{stats['slo_missed_shed']} shed; "
          f"{stats['aging_promotions']} aging promotions")
    print(f"degradation census: {stats['preemptions']} preemptions, "
          f"{stats['swap_bursts']} swap bursts, "
          f"{stats['bursts_retried']} bursts retried, "
          f"{stats['faults_recovered']} faults recovered")
    starved = rec.starved()
    if starved:
        print(f"STARVED (neither retired nor shed): rids {starved}")
        sys.exit(1)

    if not args.no_bench and args.bench_out:
        run_record = {
            "git_sha": _git_sha(),
            "date": datetime.datetime.now(
                datetime.timezone.utc).isoformat(),
            "hostname": socket.gethostname(),
            "jax": jax.__version__,
            "mode": mode,
            "workload": {
                "arch": cfg.name, "traffic": dataclasses.asdict(tcfg),
                "t_max": t_max, "max_slots": args.max_slots,
                "page_size": args.page_size, "pool_pages": args.pool_pages,
                "preempt": args.preempt, "aging": args.aging,
                "max_queue": args.max_queue, "replicas": args.replicas,
                "wall_s": dt},
            "cells": dict(report, census=_census(stats)),
        }
        _append_run(args.bench_out, run_record)
        print(f"# appended run to {args.bench_out}")


if __name__ == "__main__":
    main()
