"""Checkpointing: atomic, resumable, mesh-elastic.

State pytrees are flattened with key paths; leaves are gathered to host numpy
and written to a per-step directory via ``np.savez`` plus a JSON manifest.
Writes are atomic (tmp dir + rename) so a preemption mid-save never corrupts
the latest checkpoint.  Restore maps leaves back by key path onto a template
pytree and ``device_put``s with the *target* sharding — which may belong to a
different mesh than the one that saved (elastic re-mesh: checkpoints are
host-side and layout-free).
"""

from __future__ import annotations

import json
import os
import re
import shutil
import tempfile
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np


def _flatten(tree) -> dict:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = jax.tree_util.keystr(path)
        arr = np.asarray(jax.device_get(leaf))
        if arr.dtype.kind == "V" or str(arr.dtype) == "bfloat16":
            # ml_dtypes (bf16/fp8) do not survive np.savez — store as f32,
            # which is exact for bf16 (f32 is a superset); restore casts back.
            arr = arr.astype(np.float32)
        flat[key] = arr
    return flat


def save_checkpoint(directory: str, step: int, state: Any,
                    extra: Optional[dict] = None) -> str:
    os.makedirs(directory, exist_ok=True)
    flat = _flatten(state)
    tmp = tempfile.mkdtemp(dir=directory, prefix=".tmp_")
    try:
        np.savez(os.path.join(tmp, "arrays.npz"),
                 **{k: v for k, v in flat.items()})
        manifest = {"step": step, "keys": sorted(flat.keys()),
                    "extra": extra or {}}
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        final = os.path.join(directory, f"step_{step:08d}")
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)                      # atomic publish
        return final
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = [int(m.group(1)) for d in os.listdir(directory)
             if (m := re.fullmatch(r"step_(\d+)", d))]
    return max(steps) if steps else None


def restore_checkpoint(directory: str, step: int, template: Any,
                       shardings: Any = None) -> tuple[Any, dict]:
    """Restore onto ``template`` structure; optionally placed with
    ``shardings`` (same pytree structure, NamedSharding leaves) — the target
    mesh need not match the saving mesh."""
    path = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    arrays = np.load(os.path.join(path, "arrays.npz"))
    paths, treedef = jax.tree_util.tree_flatten_with_path(template)
    shard_leaves = (jax.tree_util.tree_leaves(shardings)
                    if shardings is not None else [None] * len(paths))
    leaves = []
    for (kp, leaf), shd in zip(paths, shard_leaves):
        key = jax.tree_util.keystr(kp)
        if key not in arrays:
            raise KeyError(f"checkpoint missing leaf {key}")
        arr = arrays[key]
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(f"shape mismatch for {key}: "
                             f"{arr.shape} vs {leaf.shape}")
        arr = np.asarray(jnp.asarray(arr).astype(leaf.dtype))
        leaves.append(jax.device_put(arr, shd) if shd is not None
                      else jax.device_put(arr))
    return jax.tree_util.tree_unflatten(treedef, leaves), manifest["extra"]


class CheckpointManager:
    """Keeps the last ``keep`` checkpoints, saves every ``every`` steps."""

    def __init__(self, directory: str, every: int = 100, keep: int = 3):
        self.directory = directory
        self.every = every
        self.keep = keep

    def maybe_save(self, step: int, state, extra: Optional[dict] = None):
        if step % self.every:
            return None
        path = save_checkpoint(self.directory, step, state, extra)
        self._gc()
        return path

    def _gc(self):
        if not os.path.isdir(self.directory):
            return
        steps = sorted(int(m.group(1)) for d in os.listdir(self.directory)
                       if (m := re.fullmatch(r"step_(\d+)", d)))
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:08d}"),
                          ignore_errors=True)
