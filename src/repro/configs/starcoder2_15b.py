"""StarCoder2-15B [arXiv:2402.19173; hf] — dense GQA + RoPE code LM."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-15b", family="dense",
    n_layers=40, d_model=6144, n_heads=48, n_kv_heads=4, d_ff=24576,
    vocab_size=49152, head_dim=128, mlp="gelu", norm="ln",
    rope_theta=100_000.0, tie_embeddings=True,
    sharding_profile="tp_heads", subquadratic=False,
)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="starcoder2-smoke", family="dense",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=256,
        vocab_size=512, mlp="gelu", norm="ln", remat="none")
