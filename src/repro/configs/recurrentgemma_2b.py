"""RecurrentGemma-2B [arXiv:2402.19427; hf] — RG-LRU + local attention, 1:2.

Griffin pattern (R, R, L): two recurrent blocks per local-MQA (window 2048)
block; 26 layers = 8 x "RRL" + "RR" tail.  10 heads on a 16-way model axis →
sequence-parallel profile.
"""

from repro.configs.base import ModelConfig, RGLRUConfig

CONFIG = ModelConfig(
    name="recurrentgemma-2b", family="hybrid",
    n_layers=26, d_model=2560, n_heads=10, n_kv_heads=1, d_ff=7680,
    vocab_size=256000, head_dim=256, mlp="geglu", norm="rms",
    block_pattern="RRL", sliding_window=2048,
    rglru=RGLRUConfig(lru_width=2560, conv_width=4),
    sharding_profile="sp_seq", subquadratic=True,
)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="recurrentgemma-smoke", family="hybrid",
        n_layers=5, d_model=48, n_heads=2, n_kv_heads=1, d_ff=96,
        vocab_size=384, block_pattern="RRL", sliding_window=8,
        rglru=RGLRUConfig(lru_width=48), mlp="geglu", remat="none",
        subquadratic=True)
