"""Whisper-medium [arXiv:2212.04356] — encoder-decoder; conv frontend STUB.

24 encoder + 24 decoder layers, d_model 1024, MHA (kv=16), LayerNorm, GeLU.
``input_specs`` supplies the 1500 precomputed frame embeddings the conv
downsampler would produce.
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-medium", family="audio",
    n_layers=24, d_model=1024, n_heads=16, n_kv_heads=16, d_ff=4096,
    vocab_size=51865, head_dim=64, mlp="gelu", norm="ln",
    encoder_layers=24, encoder_seq=1500, tie_embeddings=True,
    sharding_profile="tp_heads", subquadratic=False,
)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="whisper-smoke", family="audio",
        n_layers=2, d_model=48, n_heads=4, n_kv_heads=4, d_ff=96,
        vocab_size=256, mlp="gelu", norm="ln",
        encoder_layers=2, encoder_seq=8, remat="none")
