"""Configuration schema for every architecture and run in the framework.

``ModelConfig`` is a frozen dataclass consumed by ``repro.models`` (family
dispatch), ``repro.parallel`` (sharding rules) and ``repro.launch`` (dry-run,
train, serve).  One module per assigned architecture lives in this package and
exports ``CONFIG`` (exact paper/assignment numbers) and ``smoke()`` (a reduced
same-family config for CPU tests).
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class PortSpec:
    """One logical stream attached to the fabric (an accelerator-side port).

    The paper's port is a W_acc-wide read or write channel into the
    transposition network; in the framework a port is a named consumer stream
    (KV read, KV write, weight stream, MoE dispatch) that the burst scheduler
    multiplexes through the shared read/write networks.

    ``offset``/``words`` are the stream's extent on the packed burst's word
    axis: the scheduler folds each stream's line groups into the word axis,
    so a stream occupies ``words`` contiguous word lanes starting at
    ``offset`` within its dtype group's ``[N, N, W_total]`` tile — the
    framework form of the paper's per-port head/tail pointers.  The extents
    are recorded at enqueue time regardless of ``FabricConfig.pack`` and
    describe the burst that packs every enqueued stream of the dtype; when
    the kernelized fabric peels sparse-extent streams into their own fused
    launches, the scheduler re-derives the dense remainder's offsets over
    the streams actually packed (the enqueue-time values remain the
    observability record, not the slicing authority).

    ``gathered``/``pool_words`` are the sparse-extent mode (the head/tail
    pointers generalized to a scatter list): a gathered stream names its
    lines by an explicit frame-index operand into a larger backing region
    (a paged KV pool), so the burst carries only ``words`` live words while
    ``pool_words`` records the backing extent the indices address — the
    traffic the gather-after-burst fallback would have moved instead.
    """
    name: str
    direction: str = "read"       # read | write
    lanes: int = 1                # W_acc multiplier for this stream
    offset: int = 0               # word-axis offset within the packed burst
    words: int = 0                # word-axis extent (0 = not yet scheduled)
    gathered: bool = False        # sparse extent: lines named by an index list
    pool_words: int = 0           # backing extent the gather indices address


@dataclasses.dataclass(frozen=True)
class FabricConfig:
    """Parameters of the memory-movement fabric (paper §III design point).

    ``n_ports`` is N = W_line / W_acc (ports per direction), ``lane_width``
    the per-port word width W_acc in elements, so one DRAM line carries
    ``line_width = n_ports * lane_width`` elements.  ``impl`` selects the
    data-transfer network: the paper's transposition network ("medusa"), the
    gather-based baseline ("crossbar"), plain reshape/swapaxes semantics
    ("oracle"), or "fused" (beyond-paper: the layout conversion is elided
    into the consumer's contraction).  ``burst_len`` is MaxBurstLen (lines
    buffered per port, §III-C); ``page_size`` the KV-cache page granularity
    in timesteps (one page = a burst of ``page_size`` lines); ``tile`` the
    exchange-network tile edge (0 = largest power-of-two that fits).

    ``pack`` selects how the burst scheduler merges streams that share a
    dtype: ``"packed"`` concatenates them along the word axis (zero padding
    moves through the network — the §III-C deep-narrow banks with per-port
    extents); ``"pad"`` pads every stream to the widest word and concatenates
    along the line axis (kept for A/B benchmarking of the packing win).

    ``word_fold`` caps machine-word lane folding on bursts: adjacent narrow
    words fold into wider machine words before the network runs (bf16/u16
    pairs into u32; quads into u64 under x64), halving/quartering the lane
    count every exchange stage touches — exact unfold on arrival, bit-parity
    guaranteed since the networks are pure word movement.  ``"auto"``
    (default) folds as wide as the dtype, stream geometry and enabled
    machine words allow; ``1`` disables; ``2``/``4`` cap the factor.
    Streams whose word counts don't divide the factor fall back gracefully
    (the whole dtype group folds at the largest factor every member
    supports).  Both layouts fold: ``"packed"`` per stream geometry,
    ``"pad"`` on the padded word axis (so the pack A/B isolates the packing
    effect from the lane width).

    ``paged_pool`` selects the serving engine's KV storage: ``True`` (the
    default) backs every full-attention cache leaf with one shared physical
    page pool plus a per-slot logical→physical page table (gather-based
    decode, free-list allocation, true reclamation — short and long
    sequences share HBM); ``False`` keeps the dense per-slot reservation
    (``[max_slots, t_max]`` regions, the A/B baseline and the bit-parity
    reference).

    ``fused_gather`` selects where the pool's logical→physical gather
    happens relative to the networks: ``"auto"``/``True`` makes it part of
    the fabric contract (sparse-extent streams — the burst carries only the
    frames the page table maps, ``words_moved`` scales with live tokens;
    on the kernelized medusa fabric the indices ride the fused burst kernel
    as a prefetched operand, vLLM paged-attention style), ``False`` keeps
    the gather as a consumer-side postprocess on the banked full pool (the
    gather-after-burst fallback — the network moves every pool frame).
    ``"auto"`` (default) follows ``paged_pool``.

    ``preempt`` selects what the serving engine does when a higher-priority
    request would otherwise wait on a full pool: ``"swap"`` (the default)
    evicts a victim slot's pages to host memory over the fabric — swap-out
    rides the read network's fused page-table gather, swap-in the write
    network's scatter, as ``swap/*`` sparse-extent streams — and re-admits
    the victim ahead of the queue later; ``"recompute"`` drops the victim's
    pages and re-prefills its prompt + generated prefix on re-admission
    (cheaper than swapping when the sequence is short — the vLLM
    tradeoff); ``"off"`` keeps the seed engine's head-of-line blocking.
    ``swap_space_pages`` caps the host swap space (in pages); a swap-out
    that would exceed it degrades to recompute for that victim.  ``0``
    (default) means unbounded.

    ``pool_shards`` shards the physical page pool over a ``pool`` device
    mesh axis: every full-attention leaf's page axis splits into
    ``pool_shards`` contiguous blocks (the :func:`~repro.fabric.sharded.
    pool_partition_spec` ``PartitionSpec``), the sparse-extent bursts lower
    inside ``shard_map`` as a two-hop collective (local fused gather of the
    frames each shard owns, then one all-to-all delivering them to the
    requesting shard), and the :class:`~repro.fabric.PagePool` stripes
    allocation round-robin across the shard blocks so decode traffic
    balances.  ``1`` (default) keeps the single-device lowering.
    ``collective`` picks the inter-shard exchange: ``"all_to_all"`` (XLA's
    monolithic collective) or ``"ring"`` (N-1 ``ppermute`` rotation steps —
    the §III-A diagonal schedule at mesh scale; see
    ``repro.parallel.collectives``).
    """
    n_ports: int = 8
    lane_width: int = 64
    impl: str = "medusa"          # medusa | crossbar | oracle | fused
    tile: int = 0
    burst_len: int = 32
    page_size: int = 64
    pack: str = "packed"          # packed | pad
    word_fold: "str | int" = "auto"   # auto | 1 | 2 | 4
    paged_pool: bool = True       # serving engine: shared physical page pool
    fused_gather: "str | bool" = "auto"   # auto | True | False
    pool_shards: int = 1          # pool-axis shards over the device mesh
    collective: str = "all_to_all"    # all_to_all | ring
    preempt: str = "swap"         # swap | recompute | off
    swap_space_pages: int = 0     # host swap-space cap in pages (0 = unbounded)

    @property
    def line_width(self) -> int:
        """W_line: elements per DRAM line."""
        return self.n_ports * self.lane_width

    @property
    def fused_gather_on(self) -> bool:
        """Whether the paged gather/scatter is part of the fabric contract
        (sparse-extent bursts) rather than a consumer-side postprocess."""
        if self.fused_gather == "auto":
            return self.paged_pool
        return bool(self.fused_gather)

    def validate(self) -> "FabricConfig":
        if self.impl not in ("medusa", "crossbar", "oracle", "fused"):
            raise ValueError(f"unknown fabric impl {self.impl!r}")
        if self.pack not in ("packed", "pad"):
            raise ValueError(f"unknown burst packing {self.pack!r}")
        if self.word_fold not in ("auto", 1, 2, 4):
            raise ValueError(f"word_fold must be 'auto', 1, 2 or 4, "
                             f"got {self.word_fold!r}")
        if self.fused_gather not in ("auto", True, False):
            raise ValueError(f"fused_gather must be 'auto', True or False, "
                             f"got {self.fused_gather!r}")
        if self.pool_shards < 1:
            raise ValueError(f"pool_shards must be >= 1, "
                             f"got {self.pool_shards}")
        if self.collective not in ("all_to_all", "ring"):
            raise ValueError(f"collective must be 'all_to_all' or 'ring', "
                             f"got {self.collective!r}")
        if self.preempt not in ("swap", "recompute", "off"):
            raise ValueError(f"preempt must be 'swap', 'recompute' or 'off', "
                             f"got {self.preempt!r}")
        if self.swap_space_pages < 0:
            raise ValueError(f"swap_space_pages must be >= 0, "
                             f"got {self.swap_space_pages}")
        if self.n_ports < 1 or self.lane_width < 1:
            raise ValueError(f"bad fabric geometry N={self.n_ports} "
                             f"W_acc={self.lane_width}")
        if self.page_size < 1 or self.burst_len < 1:
            raise ValueError(f"bad fabric buffering page_size={self.page_size} "
                             f"burst_len={self.burst_len}")
        return self


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    expert_d_ff: int
    capacity_factor: float = 1.25
    # "medusa" = ring-rotation (ppermute) dispatch schedule; "xla" = all_to_all.
    dispatch: str = "xla"
    # pad the expert dim to this count with never-routed dead experts so EP
    # divides the mesh evenly (beyond-paper optimisation; 0 = no padding).
    pad_to: int = 0

    @property
    def n_experts_padded(self) -> int:
        return max(self.pad_to, self.n_experts)


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_state: int = 128
    head_dim: int = 64
    expand: int = 2
    conv_width: int = 4
    chunk: int = 128              # SSD chunk length for training


@dataclasses.dataclass(frozen=True)
class RGLRUConfig:
    lru_width: int = 0            # 0 → d_model
    conv_width: int = 4
    c: float = 8.0                # RG-LRU gate sharpness constant


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                   # dense | ssm | hybrid | moe | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0             # 0 → d_model // n_heads
    # --- attention pattern -------------------------------------------------
    # 'A' full attention, 'L' local sliding-window, 'R' recurrent (RG-LRU),
    # 'M' mamba2/SSD.  The pattern tiles over layers (truncated to n_layers).
    block_pattern: str = "A"
    sliding_window: int = 0       # window for 'L' layers
    rope_theta: float = 10_000.0
    rope_theta_global: float = 0.0  # gemma3 uses a larger theta on 'A' layers
    norm: str = "rms"             # rms | ln
    mlp: str = "swiglu"           # swiglu | geglu | gelu
    tie_embeddings: bool = True
    # --- sub-family configs --------------------------------------------------
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    rglru: Optional[RGLRUConfig] = None
    # --- enc-dec (whisper) ----------------------------------------------------
    encoder_layers: int = 0       # >0 → encoder-decoder model
    encoder_seq: int = 1500       # precomputed frame embeddings (stub frontend)
    # --- vlm (internvl) ---------------------------------------------------------
    n_patches: int = 0            # >0 → patch embeddings prepended (stub frontend)
    # --- numerics / memory ------------------------------------------------------
    dtype: str = "bfloat16"
    remat: str = "full"           # full | dots | none
    scan_layers: bool = True
    # --- interconnect (the paper's feature) -------------------------------------
    kv_layout: str = "medusa"     # medusa | crossbar | oracle | fused
    # Explicit fabric geometry; None derives one from the model's KV shape
    # (ports = KV heads, lane = head_dim) and ``kv_layout``.  Consumers go
    # through ``resolved_fabric`` / ``repro.fabric.Fabric.for_model``.
    fabric: Optional[FabricConfig] = None
    # --- serving ------------------------------------------------------------------
    serve_fsdp: bool = False      # shard weights over data axis at inference
    # Medusa-style speculative decoding: k draft heads (residual projections
    # off the final-norm hidden state, sharing the unembedding) proposing
    # tokens t+1..t+k per step.  0 → no draft params, dense decode only.
    spec_heads: int = 0
    # --- parallelism ---------------------------------------------------------------
    sharding_profile: str = "tp_heads"   # tp_heads | sp_seq | moe_cap
    # --- long-context capability -------------------------------------------------
    subquadratic: bool = False    # may run long_500k

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def resolved_fabric(self) -> FabricConfig:
        """The fabric this model moves memory through.  An explicit ``fabric``
        wins; otherwise the KV-cache geometry names one: each KV head is a
        port (N = n_kv_heads) and a port word is one head vector
        (W_acc = head_dim), so a line is one timestep across heads."""
        if self.fabric is not None:
            return self.fabric.validate()
        return FabricConfig(
            n_ports=max(self.n_kv_heads, 1),
            lane_width=self.resolved_head_dim or 1,
            impl=self.kv_layout).validate()

    @property
    def param_dtype(self):
        return jnp.dtype(self.dtype)

    def layer_types(self) -> Tuple[str, ...]:
        pat = self.block_pattern
        reps = -(-self.n_layers // len(pat))
        return tuple((pat * reps)[: self.n_layers])

    # ------------------------------------------------------------------
    # Analytic parameter / FLOP accounting (used for roofline §Roofline)
    # ------------------------------------------------------------------
    def _attn_params(self) -> int:
        hd = self.resolved_head_dim
        q = self.d_model * self.n_heads * hd
        kv = 2 * self.d_model * self.n_kv_heads * hd
        o = self.n_heads * hd * self.d_model
        return q + kv + o

    def _mlp_params(self, d_ff: int) -> int:
        mult = 3 if self.mlp in ("swiglu", "geglu") else 2
        return mult * self.d_model * d_ff

    def _rglru_params(self) -> int:
        w = (self.rglru.lru_width or self.d_model) if self.rglru else self.d_model
        # in/out proj + conv + input & recurrence gates + Λ
        conv = w * self.rglru.conv_width if self.rglru else 0
        return 2 * self.d_model * w + conv + 2 * w * w + w

    def _mamba_params(self) -> int:
        s = self.ssm
        d_in = s.expand * self.d_model
        nh = d_in // s.head_dim
        in_p = self.d_model * (2 * d_in + 2 * s.d_state + nh)
        conv = s.conv_width * (d_in + 2 * s.d_state)
        out_p = d_in * self.d_model
        return in_p + conv + out_p + nh + d_in  # + dt bias, gate norm

    def param_count(self) -> int:
        """Total parameter count (embeddings included once if tied)."""
        emb = self.vocab_size * self.d_model * (1 if self.tie_embeddings else 2)
        total = emb
        for t in self.layer_types():
            if t in ("A", "L"):
                total += self._attn_params()
            elif t == "R":
                total += self._rglru_params()
            elif t == "M":
                total += self._mamba_params()
            if t != "M":  # mamba blocks replace attn+mlp in one
                if self.moe is not None:
                    total += (self.moe.n_experts * self._mlp_params(self.moe.expert_d_ff)
                              + self.d_model * self.moe.n_experts)
                else:
                    total += self._mlp_params(self.d_ff)
            total += 2 * self.d_model  # norms
        if self.encoder_layers:
            total += self.encoder_layers * (self._attn_params()
                                            + self._mlp_params(self.d_ff)
                                            + 2 * self.d_model)
            # decoder cross-attention
            total += self.n_layers * (self._attn_params() + self.d_model)
        return total

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: top-k experts only)."""
        if self.moe is None:
            return self.param_count()
        dense = self.param_count() - sum(
            self.moe.n_experts * self._mlp_params(self.moe.expert_d_ff)
            for t in self.layer_types() if t != "M")
        active = sum(self.moe.top_k * self._mlp_params(self.moe.expert_d_ff)
                     for t in self.layer_types() if t != "M")
        return dense + active


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One assigned input-shape cell."""
    name: str
    seq_len: int
    global_batch: int
    kind: str                     # train | prefill | decode

SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    """Optimizer / run-level configuration."""
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 1000
    weight_decay: float = 0.1
    beta1: float = 0.9
    beta2: float = 0.95
    grad_clip: float = 1.0
    zero1: bool = True            # shard optimizer state over data axis
    grad_accum: int = 0           # microbatches per step; 0 = auto-fit HBM
    grad_compression: str = "none"  # none | int8
    checkpoint_every: int = 100
    checkpoint_dir: str = "/tmp/repro_ckpt"
    seed: int = 0
