"""StableLM-2-1.6B [hf:stabilityai/stablelm-2-1_6b] — dense MHA (kv=32)."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="stablelm-1.6b", family="dense",
    n_layers=24, d_model=2048, n_heads=32, n_kv_heads=32, d_ff=5632,
    vocab_size=100352, head_dim=64, mlp="swiglu", norm="ln",
    rope_theta=10_000.0, tie_embeddings=True,
    sharding_profile="tp_heads", subquadratic=False,
)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="stablelm-smoke", family="dense",
        n_layers=2, d_model=48, n_heads=4, n_kv_heads=4, d_ff=128,
        vocab_size=384, mlp="swiglu", norm="ln", remat="none")
