from repro.configs.base import (FabricConfig, ModelConfig, MoEConfig,
                                PortSpec, SSMConfig, RGLRUConfig,
                                ShapeConfig, TrainConfig, SHAPES)
from repro.configs.registry import (ARCHS, get_config, get_fabric, get_smoke,
                                    get_shape, cells)

__all__ = ["FabricConfig", "ModelConfig", "MoEConfig", "PortSpec",
           "SSMConfig", "RGLRUConfig", "ShapeConfig", "TrainConfig", "SHAPES",
           "ARCHS", "get_config", "get_fabric", "get_smoke", "get_shape",
           "cells"]
