from repro.configs.base import (ModelConfig, MoEConfig, SSMConfig, RGLRUConfig,
                                ShapeConfig, TrainConfig, SHAPES)
from repro.configs.registry import ARCHS, get_config, get_smoke, get_shape, cells

__all__ = ["ModelConfig", "MoEConfig", "SSMConfig", "RGLRUConfig",
           "ShapeConfig", "TrainConfig", "SHAPES", "ARCHS", "get_config",
           "get_smoke", "get_shape", "cells"]
