"""Architecture registry: ``--arch <id>`` resolution for every launcher."""

from __future__ import annotations

import importlib

from repro.configs.base import FabricConfig, ModelConfig, SHAPES, ShapeConfig

ARCHS = {
    "starcoder2-15b": "repro.configs.starcoder2_15b",
    "stablelm-1.6b": "repro.configs.stablelm_1_6b",
    "gemma3-12b": "repro.configs.gemma3_12b",
    "gemma3-4b": "repro.configs.gemma3_4b",
    "internvl2-1b": "repro.configs.internvl2_1b",
    "mamba2-780m": "repro.configs.mamba2_780m",
    "recurrentgemma-2b": "repro.configs.recurrentgemma_2b",
    "granite-moe-3b-a800m": "repro.configs.granite_moe_3b_a800m",
    "kimi-k2-1t-a32b": "repro.configs.kimi_k2_1t_a32b",
    "whisper-medium": "repro.configs.whisper_medium",
}


def get_config(arch: str) -> ModelConfig:
    if arch not in ARCHS:
        raise KeyError(f"unknown arch {arch!r}; available: {sorted(ARCHS)}")
    return importlib.import_module(ARCHS[arch]).CONFIG


def get_smoke(arch: str) -> ModelConfig:
    return importlib.import_module(ARCHS[arch]).smoke()


def get_shape(name: str) -> ShapeConfig:
    return SHAPES[name]


def get_fabric(arch: str) -> FabricConfig:
    """The memory-movement fabric an architecture names (explicit
    ``ModelConfig.fabric`` or the one derived from its KV geometry)."""
    return get_config(arch).resolved_fabric


def cells():
    """All (arch, shape) dry-run cells, with documented long_500k skips."""
    out = []
    for arch in ARCHS:
        cfg = get_config(arch)
        for shape in SHAPES.values():
            skip = (shape.name == "long_500k" and not cfg.subquadratic)
            out.append((arch, shape.name, skip))
    return out
