"""Granite-3.0-3B-A800M MoE [hf:ibm-granite] — 40 experts top-8.

40 experts cannot split a 16-way model axis evenly → the "moe_cap" profile
shards the expert *capacity* dim over model and runs attention sequence-
parallel (24 heads % 16 != 0 as well).
"""

from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="granite-moe-3b-a800m", family="moe",
    n_layers=32, d_model=1536, n_heads=24, n_kv_heads=8, d_ff=512,
    vocab_size=49155, head_dim=64, mlp="swiglu", norm="rms",
    moe=MoEConfig(n_experts=40, top_k=8, expert_d_ff=512),
    sharding_profile="moe_cap", subquadratic=False,
)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="granite-moe-smoke", family="moe",
        n_layers=2, d_model=32, n_heads=4, n_kv_heads=2, d_ff=64,
        vocab_size=256, moe=MoEConfig(n_experts=4, top_k=2, expert_d_ff=64),
        remat="none")
