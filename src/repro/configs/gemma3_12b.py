"""Gemma-3-12B [hf:google/gemma-3 family] — 5:1 local:global attention.

Pattern "LLLLLA": five sliding-window (1024) layers per one global layer;
local layers use theta 10k, global layers 1M (128k context recipe).
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-12b", family="dense",
    n_layers=48, d_model=3840, n_heads=16, n_kv_heads=8, d_ff=15360,
    vocab_size=262144, head_dim=256, mlp="geglu", norm="rms",
    block_pattern="LLLLLA", sliding_window=1024,
    rope_theta=10_000.0, rope_theta_global=1_000_000.0,
    sharding_profile="tp_heads", subquadratic=True,
)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="gemma3-smoke", family="dense",
        n_layers=6, d_model=64, n_heads=4, n_kv_heads=2, d_ff=192,
        vocab_size=512, head_dim=16, mlp="geglu", block_pattern="LLLLLA",
        sliding_window=8, rope_theta_global=1_000_000.0, remat="none",
        subquadratic=True)
