"""Mamba2-780M [arXiv:2405.21060] — attention-free SSD (state-space duality)."""

from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="mamba2-780m", family="ssm",
    n_layers=48, d_model=1536, n_heads=1, n_kv_heads=1, d_ff=0,
    vocab_size=50280, block_pattern="M",
    ssm=SSMConfig(d_state=128, head_dim=64, expand=2, conv_width=4, chunk=256),
    mlp="gelu", norm="rms",
    sharding_profile="tp_heads", subquadratic=True,
)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="mamba2-smoke", family="ssm",
        n_layers=3, d_model=64, n_heads=1, n_kv_heads=1, d_ff=0,
        vocab_size=384, block_pattern="M",
        ssm=SSMConfig(d_state=16, head_dim=16, expand=2, chunk=8),
        remat="none", subquadratic=True)
