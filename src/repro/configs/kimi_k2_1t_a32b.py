"""Kimi-K2 1T-A32B [arXiv:2501 (paper-table)] — trillion-param MoE.

61 layers, 384 experts top-8, d_ff=2048 per expert, GQA kv=8 per the
assignment.  384 experts / 16-way model axis = 24 experts per chip (EP);
heads 64/16 = 4 per chip (TP).  Training state does not fit 512 v5e chips
(physics — see EXPERIMENTS.md §Dry-run); serving does.
"""

from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b", family="moe",
    n_layers=61, d_model=7168, n_heads=64, n_kv_heads=8, d_ff=2048,
    vocab_size=163840, head_dim=128, mlp="swiglu", norm="rms",
    moe=MoEConfig(n_experts=384, top_k=8, expert_d_ff=2048),
    rope_theta=50_000.0,
    sharding_profile="tp_heads", subquadratic=False,
)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="kimi-k2-smoke", family="moe",
        n_layers=3, d_model=32, n_heads=4, n_kv_heads=2, d_ff=64,
        vocab_size=256, moe=MoEConfig(n_experts=8, top_k=2, expert_d_ff=32),
        remat="none")
