"""InternVL2-1B [arXiv:2404.16821; hf] — ViT frontend (stub) + LM backbone.

The assignment specifies the transformer backbone only; ``input_specs``
supplies 256 precomputed patch embeddings prepended to the text tokens.
14 heads cannot split a 16-way model axis → sequence-parallel profile.
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-1b", family="vlm",
    n_layers=24, d_model=896, n_heads=14, n_kv_heads=2, d_ff=4864,
    vocab_size=151655, head_dim=64, mlp="swiglu", norm="rms",
    rope_theta=1_000_000.0, n_patches=256,
    sharding_profile="sp_seq", subquadratic=False,
)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="internvl2-smoke", family="vlm",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
        vocab_size=384, n_patches=4, remat="none")
