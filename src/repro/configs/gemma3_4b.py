"""Gemma-3-4B [hf:google/gemma-3 family] — 5:1 local:global, 34 layers.

8 heads cannot split a 16-way model axis → sequence-parallel profile.
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-4b", family="dense",
    n_layers=34, d_model=2560, n_heads=8, n_kv_heads=4, d_ff=10240,
    vocab_size=262144, head_dim=256, mlp="geglu", norm="rms",
    block_pattern="LLLLLA", sliding_window=1024,
    rope_theta=10_000.0, rope_theta_global=1_000_000.0,
    sharding_profile="sp_seq", subquadratic=True,
)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="gemma3-4b-smoke", family="dense",
        n_layers=8, d_model=48, n_heads=4, n_kv_heads=2, d_ff=128,
        vocab_size=384, head_dim=16, mlp="geglu", block_pattern="LLLLLA",
        sliding_window=8, rope_theta_global=1_000_000.0, remat="none",
        subquadratic=True)
