"""Slot-based continuous-batching serving engine.

A fixed decode batch of ``max_slots`` sequences advances one token per step;
finished sequences retire and their slots are immediately refilled from the
queue (prefill splices the new request's KV into the batched cache at the
slot index).  Per-slot positions are first-class in the decode path
(``models.common._cache_write`` and friends), so slots at different depths
coexist in one batched step — the production pattern behind vLLM-style
serving, on top of the Medusa KV layout engine.

Decoder-only families (dense/moe/ssm/hybrid/vlm); greedy sampling.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import api
from repro.models import lm


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray                     # [prompt_len] int32
    max_new_tokens: int
    generated: list = dataclasses.field(default_factory=list)
    done: bool = False


class ServingEngine:
    def __init__(self, cfg: ModelConfig, params, max_slots: int, t_max: int):
        assert cfg.family != "audio", "engine covers decoder-only families"
        self.cfg = cfg
        self.params = params
        self.max_slots = max_slots
        self.t_max = t_max
        self.caches = api.init_cache(cfg, max_slots, t_max)
        self.pos = np.zeros((max_slots,), np.int32)      # next write position
        self.active: List[Optional[Request]] = [None] * max_slots
        self.tokens = np.zeros((max_slots, 1), np.int32)
        self.queue: List[Request] = []

        self._decode = jax.jit(
            lambda p, tok, caches, pos: api.decode_fn(p, tok, caches, pos, cfg))

    # -- admission -----------------------------------------------------------
    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def _admit(self) -> None:
        for slot in range(self.max_slots):
            if self.active[slot] is not None or not self.queue:
                continue
            req = self.queue.pop(0)
            prompt = jnp.asarray(req.prompt)[None, :]
            logits, req_cache = api.prefill_fn(
                self.params, {"tokens": prompt}, self.cfg, self.t_max)
            self._splice(req_cache, slot)
            self.active[slot] = req
            self.pos[slot] = len(req.prompt)
            first = int(np.argmax(np.asarray(logits[0, -1])))
            req.generated.append(first)
            self.tokens[slot, 0] = first

    def _splice(self, req_cache, slot: int) -> None:
        """Insert a single-request cache into the batch cache at ``slot``."""
        def one(batch_leaf, req_leaf):
            # batch dim is axis 1 for stacked 'unit' leaves, axis 0 for tail
            axis = 1 if batch_leaf.ndim >= 4 and batch_leaf.shape[1] == \
                self.max_slots else 0
            idx = [slice(None)] * batch_leaf.ndim
            idx[axis] = slice(slot, slot + 1)
            return batch_leaf.at[tuple(idx)].set(req_leaf)
        self.caches = jax.tree.map(one, self.caches, req_cache)

    # -- one engine step -----------------------------------------------------
    def step(self) -> int:
        """Admit + one batched decode step; returns #active sequences."""
        self._admit()
        live = [s for s in range(self.max_slots) if self.active[s] is not None]
        if not live:
            return 0
        logits, self.caches = self._decode(
            self.params, jnp.asarray(self.tokens), self.caches,
            jnp.asarray(self.pos))
        nxt = np.asarray(jnp.argmax(logits[:, 0], axis=-1), np.int32)
        for s in live:
            req = self.active[s]
            self.pos[s] += 1
            req.generated.append(int(nxt[s]))
            self.tokens[s, 0] = int(nxt[s])
            if (len(req.generated) >= req.max_new_tokens
                    or self.pos[s] + 1 >= self.t_max):
                req.done = True
                self.active[s] = None
        # idle slots keep position 0 and a dummy token; their cache rows are
        # garbage but masked out by their own (stale) positions — they are
        # overwritten at admission.
        return len([s for s in range(self.max_slots)
                    if self.active[s] is not None])

    def run_to_completion(self, max_steps: int = 10_000) -> None:
        for _ in range(max_steps):
            if self.step() == 0 and not self.queue:
                return
