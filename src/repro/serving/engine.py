"""Slot-based continuous-batching serving engine on the paged KV layout.

A fixed decode batch of ``max_slots`` sequences advances one token per step;
finished sequences retire and their slots are immediately refilled from the
queue.  KV storage goes through :class:`repro.fabric.PagedKVCache`: each
slot's time axis is divided into fixed-size pages (``page_size`` timesteps =
a burst of lines through the fabric).  Under ``FabricConfig.paged_pool``
(the default) the pages live in one **shared physical pool** per
full-attention leaf — free-list allocation at admission and decode growth,
true reclamation at retirement, per-slot logical→physical page table as a
decode-step operand (gather-based attention) — so short and long sequences
share HBM and ``kv.occupancy`` measures real frames.  Admission installs
each wave's page-aligned KV extents through one ``prefill/*`` write burst
(1 network call per dtype; per-layer splice as the off-geometry fallback)
instead of the seed engine's full ``t_max`` splice-copy.  Per-slot
positions are first-class in the decode
path (``models.common._cache_write`` and friends), so slots at different
depths coexist in one batched step — the production pattern behind
vLLM-style serving, on top of the Medusa KV layout engine
(``cfg.resolved_fabric``).

The decode step is the burst scheduler's first production consumer: a
:class:`repro.fabric.BurstScheduler` instance per step hoists every
full-attention leaf's port-major conversion into one shared read burst,
runs attention in port-major space, and restores line-major caches through
one write burst — 1 read + 1 write network invocation per dtype per step
(``fabric_stats``), with the ``serve_fsdp`` weight stream riding the same
read burst.  Bit-identical to the per-layer path.  The bursts ride the
fabric's machine-word lane folding (``FabricConfig.word_fold``) and, on the
medusa fabric with kernels enabled, lower as one fused Pallas launch per
direction per dtype (``fabric_stats.words_folded`` / ``.kernel_bursts``).

Under the **fused-gather contract** (``FabricConfig.fused_gather``, auto-on
with the pool) the pool's logical→physical indirection moves into those
bursts: each engine step plans its live frames host-side
(:func:`repro.models.common.page_live_plan`, bucketed to bound retraces)
and the KV streams become sparse-extent — the networks bank only the
frames the page table maps, so decode traffic scales with live tokens
instead of pool capacity (``fabric_stats.words_live`` /
``.gather_fused_bursts``); the gather-after-burst form stays as the
fallback (``fused_gather=False``) and the bit-parity reference.

**Graceful degradation under oversubscription** (``FabricConfig.preempt``):
requests carry priority classes and optional SLO deadlines, and when a
higher-priority request would otherwise wait on a full pool the engine
preempts live slots — victims picked lowest-priority first, then
most-pages, then LRU — and parks them in a host swap space.  Eviction and
re-admission are fabric traffic like everything else: ``swap/<slot>/*``
sparse-extent streams ride the read network's fused page-table gather out
and the write network's scatter back in
(:meth:`repro.fabric.PagedKVCache.swap_out` / ``swap_in``), parity-checked
end to end, so a preempted request resumes bit-identically.  The vLLM-style
swap-vs-recompute choice (``preempt="recompute"``, or automatically when
the swap space is full or nothing was decoded yet) drops the pages and
re-prefills the sequence so far instead.  Swapped requests re-admit ahead
of the queue.  A :class:`repro.runtime.fault_tolerance.FaultInjector`
plugs into the same path: injected pool exhaustion backs admission off a
step, corrupted swap bursts are caught by the parity word and retried, and
a mid-step failure rolls the engine back to its pre-step snapshot and
replays (``fabric_stats.faults_recovered``).

**Admission control under production-shaped load** closes the scheduling
layer above the fabric: every request is stamped with an ``arrival_step``
at :meth:`submit` (the clock for queue wait, TTFT and aging), the submit
queue is bounded (``max_queue`` — overflow sheds with backpressure instead
of growing without bound), **aging** (``aging=K`` steps per class) raises a
waiting request's *effective* priority so the strict ``(-priority,
deadline, arrival)`` order can no longer starve low classes indefinitely,
and SLO-aware **load shedding** rejects a request at admission the moment
its deadline is provably unmeetable given pool headroom and queue depth —
counted (``requests_shed``/``shed_deadline``/``shed_queue_full``) instead
of missing silently at retirement, with the deadline-miss census split
into ``slo_missed_served`` / ``slo_missed_shed`` by exit path.  The
production-shaped traffic harness driving all of this lives in
:mod:`repro.serving.traffic` (seeded generator, ``MetricsRecorder``
lifecycle stamps, in-process replica router) with the
``launch/loadgen.py`` CLI on top.

Decoder-only families (dense/moe/ssm/hybrid/vlm); greedy sampling.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.fabric import (BurstScheduler, Fabric, PagedKVCache,
                          SchedulerStats, SwapRecord, make_pool_mesh,
                          shard_plan)
from repro.models import api
from repro.models import common as cm
from repro.models import lm
from repro.models import moe as moe_mod


def _lead_prod(flat) -> int:
    """Product of a flattened pool leaf's leading (layer-stack) axes."""
    reps = 1
    for s in flat.shape[:-3]:
        reps *= s
    return reps


@dataclasses.dataclass(eq=False)           # identity equality: the prompt
class Request:                             # array makes field-eq ambiguous
    rid: int
    prompt: np.ndarray                     # [prompt_len] int32
    max_new_tokens: int
    priority: int = 0                      # higher preempts strictly lower
    deadline: Optional[int] = None         # SLO: retire by this engine step
    generated: list = dataclasses.field(default_factory=list)
    done: bool = False
    arrival_step: int = -1                 # engine step at submit() — the
    #                                        clock for queue wait and aging
    shed_reason: Optional[str] = None      # set when load-shed, never served
    _seq: int = dataclasses.field(default=0, repr=False)   # submit order


@dataclasses.dataclass
class _Swapped:
    """A preempted request parked in the host swap space.  ``record`` is
    the fabric-staged KV image (swap arm) or ``None`` (recompute arm:
    re-admission re-prefills ``prompt + generated[:-1]``)."""

    req: Request
    record: Optional[SwapRecord]
    pos: int                               # next write position at eviction
    token: int                             # the pending decode token


class ServingEngine:
    def __init__(self, cfg: ModelConfig, params, max_slots: int, t_max: int,
                 page_size: int = 0, paged_pool: Optional[bool] = None,
                 pool_pages: int = 0, prefill_burst: Optional[bool] = None,
                 fused_gather: Optional[bool] = None, pool_shards: int = 0,
                 collective: Optional[str] = None,
                 preempt: Optional[str] = None,
                 swap_space_pages: Optional[int] = None,
                 check_pool: bool = False, fault_injector=None,
                 spec_decode_k: int = 0, draft_fn=None,
                 aging: int = 0, max_queue: int = 0, recorder=None):
        assert cfg.family != "audio", "engine covers decoder-only families"
        self.cfg = cfg
        # Medusa-heads speculative decoding (spec_decode_k > 0): every step
        # the model's k draft heads propose a candidate branch per slot and
        # verify_step() accepts its longest prefix against the target's
        # committed argmax — the committed token stream is the dense
        # engine's, bit for bit, because commits only ever come from the
        # real unembedding (row 0 of the step logits).  ``draft_fn(req,
        # committed) -> [k tokens]`` overrides the model heads (tests use an
        # oracle/adversarial proposer); with draft heads, params grow a
        # "draft" entry (auto-initialized when absent).
        self.spec_k = int(spec_decode_k)
        self.draft_fn = draft_fn
        self._model_draft = self.spec_k > 0 and draft_fn is None
        if self._model_draft and "draft" not in params:
            params = dict(params)
            params["draft"] = cm.draft_head_params(
                jax.random.PRNGKey(0x5BEC),
                dataclasses.replace(cfg, spec_heads=self.spec_k),
                cfg.param_dtype)
        if self._model_draft and params["draft"]["w"].shape[0] < self.spec_k:
            raise ValueError(
                f"spec_decode_k={self.spec_k} wants at least that many "
                f"draft heads; params carry "
                f"{params['draft']['w'].shape[0]}")
        self.spec_proposed = 0
        self.spec_accepted = 0
        self.spec_rejected = 0
        self._draft_queue: Dict[int, List[int]] = {}
        self.params = params
        self.max_slots = max_slots
        self.t_max = t_max
        # pool-sharded lowering (FabricConfig.pool_shards): the pool axis of
        # every full-attention leaf shards over a `pool` device-mesh axis
        # and the fused sparse bursts become per-shard gathers bridged by
        # one collective (repro.fabric.sharded) — the engine runs unchanged
        # otherwise; 0 inherits the model config's setting
        fab_cfg = cfg.resolved_fabric
        shards = pool_shards or fab_cfg.pool_shards
        if shards > 1 or collective is not None:
            fab_cfg = dataclasses.replace(
                fab_cfg, pool_shards=shards,
                collective=collective or fab_cfg.collective).validate()
        self.pool_shards = shards = fab_cfg.pool_shards
        mesh = make_pool_mesh(shards) if shards > 1 else None
        self.fabric = Fabric(fab_cfg, mesh=mesh)
        # cache depth rounds up so every full-attention leaf's line count
        # divides N and the whole cache moves through the step's shared
        # burst; positions beyond t_max are masked, so this is free capacity
        n = self.fabric.n_ports
        self.t_alloc = -(-t_max // n) * n
        ps = page_size or min(cfg.resolved_fabric.page_size, self.t_alloc)
        self.page_size = ps
        # shared physical page pool (FabricConfig.paged_pool, default on):
        # full-attention leaves become [pool_pages, ps, Hkv, D] regions
        # reached through the per-slot page table; families without
        # full-attention leaves (pure SSM/recurrent) have nothing to pool
        entries = lm.paged_entries(cfg)
        self.paged = ((cfg.resolved_fabric.paged_pool if paged_pool is None
                       else paged_pool) and bool(entries))
        if self.paged:
            pages_per_slot = -(-self.t_alloc // ps)
            pool_pages = pool_pages or max_slots * pages_per_slot
            # the pool rides the decode step's shared burst as one line
            # stream, so its frame count rounds up to a multiple of N; under
            # the sharded lowering it must also split into `shards` equal
            # contiguous page blocks (PartitionSpec("pool") ownership)
            while (pool_pages * ps) % n or pool_pages % shards:
                pool_pages += 1
        else:
            pool_pages = 0
        self.prefill_burst = prefill_burst
        # fused page-table gather (FabricConfig.fused_gather, default on
        # with the pool): the decode step's bursts bank only the frames the
        # page table maps — the engine plans the live set host-side each
        # step and passes it as operands, so network traffic scales with
        # live tokens instead of pool capacity.  Needs a fabric that banks
        # KV at all; the gather-after-burst form stays as the fallback.
        self.fused = ((cfg.resolved_fabric.fused_gather_on
                       if fused_gather is None else fused_gather)
                      and self.paged and self.fabric.banks_kv)
        if shards > 1 and not self.fused:
            raise ValueError(
                f"pool_shards={shards} needs the fused-gather pool contract "
                f"(paged pool + a fabric that banks KV) — the sharded "
                f"lowering is the sparse burst's collective form")
        # live-plan lengths quantize to whole page-of-lines buckets so the
        # jitted step retraces per occupancy *bucket*, not per page; sharded,
        # the bucket also keeps every rep's line total divisible into
        # `shards` blocks of whole N-groups (lcm, so 1 shard is unchanged)
        self.live_bucket = n * math.lcm(ps, shards)
        self.kv = PagedKVCache(
            api.init_cache(cfg, max_slots, self.t_alloc,
                           pool_pages=pool_pages, page_size=ps),
            max_slots, self.t_alloc, ps, pool_pages=pool_pages,
            paged_entries=entries if self.paged else (), fabric=self.fabric,
            fused_gather=self.fused, pool_shards=shards)
        # distinct leading rep counts over the paged leaves — the sharded
        # step carries one (fetch, place) plan per rep count
        self._shard_reps = sorted({
            max(1, _lead_prod(lm._flat_frames(self.kv.caches[kind][i]["k"])))
            for kind, i in entries}) if (self.paged and shards > 1) else []
        self.pos = np.zeros((max_slots,), np.int32)      # next write position
        self.active: List[Optional[Request]] = [None] * max_slots
        self.tokens = np.zeros((max_slots, 1), np.int32)
        self.queue: List[Request] = []
        # the step's [B, V] logits, left on device (readers pay the copy)
        self.last_logits: Optional[jax.Array] = None
        # pool mode: pages reserved per live slot for its full reach
        # (prompt + generation) — admission is the only allocation gate, so
        # decode growth can never exhaust the pool mid-flight
        self._page_reserve: dict = {}
        # preemption policy (FabricConfig.preempt): "swap" parks victims in
        # the host swap space over the fabric, "recompute" drops their pages
        # and re-prefills on re-admission, "off" is the seed head-of-line
        # gate.  Needs the page pool — dense reservations have nothing to
        # reclaim mid-flight.
        pre = fab_cfg.preempt if preempt is None else preempt
        if pre not in ("swap", "recompute", "off"):
            raise ValueError(f"preempt must be 'swap', 'recompute' or "
                             f"'off', got {pre!r}")
        self.preempt = pre if self.paged else "off"
        self.swap_space_pages = (fab_cfg.swap_space_pages
                                 if swap_space_pages is None
                                 else swap_space_pages)
        self.check_pool = check_pool
        self.fault_injector = fault_injector
        self.kv.fault_injector = fault_injector
        self._swapped: Dict[int, _Swapped] = {}      # rid → parked request
        self._admitted_at: dict = {}                 # slot → admission step
        self._swap_pages_used = 0
        self._submit_seq = 0
        self._step_count = 0
        # anti-starvation aging: every `aging` steps a candidate waits past
        # its arrival_step, its *effective* priority rises one class, so the
        # strict (-priority, deadline, arrival) order can no longer starve
        # low classes indefinitely under sustained high-priority churn.
        # 0 = off (the PR 7 strict order, exactly).
        if aging < 0:
            raise ValueError(f"aging must be >= 0 steps/class, got {aging}")
        self.aging = aging
        # bounded submit queue: submit() sheds (backpressure) once this many
        # requests are already queued.  0 = unbounded (the seed behaviour).
        if max_queue < 0:
            raise ValueError(f"max_queue must be >= 0, got {max_queue}")
        self.max_queue = max_queue
        # lifecycle observer (duck-typed, e.g. serving.traffic.
        # MetricsRecorder): record_admit/record_first_token/record_retire/
        # record_shed, all (req, step)-shaped — None = no observation
        self.recorder = recorder

        # one scheduler instance per decode step: per-step KV banking (and
        # the serve_fsdp weight stream) runs as one read + one write network
        # burst per dtype.  ``fabric_stats`` accumulates at trace time, so
        # after the first step it reads as the per-step traffic census
        # (plus one eager prefill burst per admission wave).
        self.fabric_stats = SchedulerStats()

        # MoE dispatch accounting (burst streams + the runtime-exact
        # tokens_dropped counter) routes to the same per-step stats: the
        # sink must be ambient at trace time (repro.models.moe.dispatch_stats)
        draft = self._model_draft
        if self.paged and self.fused and shards > 1:
            def _step(p, tok, caches, pos, page_table, live_idx, expand,
                      dense_pos, shard_plans):
                sched = BurstScheduler(self.fabric, stats=self.fabric_stats)
                with moe_mod.dispatch_stats(self.fabric_stats):
                    return api.decode_fn(
                        p, tok, caches, pos, cfg, sched=sched,
                        page_table=page_table, page_size=ps,
                        t_depth=self.t_alloc,
                        live_plan=(live_idx, expand, dense_pos),
                        shard_plans=shard_plans, draft=draft)
        elif self.paged and self.fused:
            def _step(p, tok, caches, pos, page_table, live_idx, expand,
                      dense_pos):
                sched = BurstScheduler(self.fabric, stats=self.fabric_stats)
                with moe_mod.dispatch_stats(self.fabric_stats):
                    return api.decode_fn(
                        p, tok, caches, pos, cfg, sched=sched,
                        page_table=page_table, page_size=ps,
                        t_depth=self.t_alloc,
                        live_plan=(live_idx, expand, dense_pos), draft=draft)
        elif self.paged:
            def _step(p, tok, caches, pos, page_table):
                sched = BurstScheduler(self.fabric, stats=self.fabric_stats)
                with moe_mod.dispatch_stats(self.fabric_stats):
                    return api.decode_fn(p, tok, caches, pos, cfg,
                                         sched=sched, page_table=page_table,
                                         page_size=ps, t_depth=self.t_alloc,
                                         draft=draft)
        else:
            def _step(p, tok, caches, pos):
                sched = BurstScheduler(self.fabric, stats=self.fabric_stats)
                with moe_mod.dispatch_stats(self.fabric_stats):
                    return api.decode_fn(p, tok, caches, pos, cfg,
                                         sched=sched, draft=draft)

        self._decode = jax.jit(_step)

    @property
    def caches(self):
        """The batched cache pytree (lives inside the paged wrapper)."""
        return self.kv.caches

    # -- admission -----------------------------------------------------------
    def submit(self, req: Request) -> str:
        """Enqueue a request; returns ``"queued"`` or ``"shed"``.

        Never-servable requests still raise (a prompt the cache can't hold,
        or — in pool mode — a reserved reach larger than the whole pool,
        which would gate the head of the queue forever); a deadlined one
        counts ``slo_missed_shed`` before the raise, so no exit path is
        uncounted.  Two admission-control gates shed instead of queueing
        (``req.shed_reason`` set, census counted, ``done`` marked so
        drivers drain):

        * **backpressure** — the bounded submit queue (``max_queue``) is
          full (``shed_queue_full``);
        * **SLO load shedding** — the deadline is provably unmeetable:
          even admitted *this* step the request cannot retire by its
          deadline, or — with preemption off, so pages and slots free only
          at retirement — the earliest live-slot retirement plus the
          request's own service floor already overshoots it
          (``shed_deadline``).  Rejecting up front beats missing silently
          at retirement.
        """
        if len(req.prompt) + 1 > self.t_max:
            self._count_shed(req, None)        # counted even though raised
            raise ValueError(
                f"request {req.rid}: prompt of {len(req.prompt)} tokens "
                f"cannot decode within t_max={self.t_max}")
        if self.kv.paged:
            reach = min(len(req.prompt) + req.max_new_tokens, self.t_max)
            need = self.kv.table.pages_for(reach)
            if need > self.kv.pool.n_pages:
                self._count_shed(req, None)
                raise ValueError(
                    f"request {req.rid}: reach of {reach} tokens reserves "
                    f"{need} pages but the pool holds {self.kv.pool.n_pages}"
                    f" — it would block the queue forever")
        req.arrival_step = self._step_count
        if self.max_queue and len(self.queue) >= self.max_queue:
            self._shed(req, "queue_full")
            return "shed"
        if req.deadline is not None and self._provably_unmeetable(req):
            self._shed(req, "deadline")
            return "shed"
        req._seq = self._submit_seq
        self._submit_seq += 1
        self.queue.append(req)
        return "queued"

    # -- SLO-aware load shedding ---------------------------------------------
    def _earliest_retire(self, req: Request, admit_step: int) -> int:
        """The provably earliest step ``req`` can retire if (re-)admitted at
        ``admit_step``: one committed token per engine step, plus the
        prefill's first token for fresh requests, capped by the cache depth
        (the ``pos + 1 >= t_max`` retirement arm) — exact, not heuristic,
        so shedding on it can never reject a meetable request."""
        g = len(req.generated)
        # fresh install appends the prefill argmax AND decodes in the same
        # step (+2); a swap-in resumes with its pending token (+1)
        first_step_tokens = 2 if g == 0 else 1
        by_tokens = req.max_new_tokens - g - first_step_tokens
        by_depth = self.t_max - len(req.prompt) - g - first_step_tokens
        return admit_step + max(0, min(by_tokens, by_depth))

    def _provably_unmeetable(self, req: Request) -> bool:
        """True when ``req.deadline`` cannot be met under ANY schedule.  The
        base proof assumes immediate admission; with preemption off the
        admission floor tightens — slots and pages free only at retirement,
        so when none are available now, the earliest admission is one step
        past the earliest *exact* live retirement (queue depth and pool
        headroom can only push it later, never earlier)."""
        admit = self._step_count
        if self.preempt == "off" and self.aging == 0:
            live = [s for s in range(self.max_slots)
                    if self.active[s] is not None]
            blocked = len(live) == self.max_slots
            if self.kv.paged and not blocked:
                reach = min(len(req.prompt) + req.max_new_tokens, self.t_max)
                blocked = (self._pool_headroom()
                           < self.kv.table.pages_for(reach))
            if blocked and live:
                admit = 1 + min(
                    self._earliest_retire(self.active[s], self._step_count)
                    for s in live)
        return self._earliest_retire(req, admit) > req.deadline

    def _count_shed(self, req: Request, reason: Optional[str]) -> None:
        stats = self.fabric_stats
        stats.requests_shed += 1
        if reason == "queue_full":
            stats.shed_queue_full += 1
        elif reason == "deadline":
            stats.shed_deadline += 1
        if req.deadline is not None:
            stats.slo_missed_shed += 1

    def _shed(self, req: Request, reason: str) -> None:
        """Reject ``req`` at admission with a counted reason — the request
        is marked done-without-output so drivers drain, and the deadline
        miss (if any) lands in ``slo_missed_shed`` instead of vanishing."""
        self._count_shed(req, reason)
        req.shed_reason = reason
        req.done = True
        if self.recorder is not None:
            self.recorder.record_shed(req, self._step_count, reason)

    def _shed_unmeetable_queued(self) -> None:
        """Admission-time recheck: a queued (or parked) request whose
        deadline became provably unmeetable while it waited is shed *now*
        — so a deadlined request can never sit in the queue past its
        deadline, and the drain census has no silent residue.  Parked
        victims release their swap space."""
        for req in [r for r in self.queue if r.deadline is not None]:
            if (self._earliest_retire(req, self._step_count) > req.deadline):
                self.queue.remove(req)
                self._shed(req, "deadline")
        for rid, sw in list(self._swapped.items()):
            req = sw.req
            if req.deadline is None:
                continue
            if self._earliest_retire(req, self._step_count) > req.deadline:
                del self._swapped[rid]
                if sw.record is not None:
                    self._swap_pages_used -= sw.record.mapped
                self._shed(req, "deadline")

    def _eff_priority(self, req: Request) -> int:
        """Effective priority under anti-starvation aging: the raw class
        plus one for every ``aging`` steps waited since arrival.  Both
        admission rank and preemption eligibility use it, so an aged
        request is not just admitted ahead of fresh higher classes — it
        can preempt them, and they cannot evict it back (its age only
        grows), which bounds every request's wait."""
        if not self.aging or req.arrival_step < 0:
            return req.priority
        return req.priority + (self._step_count - req.arrival_step) // self.aging

    def _rank(self, req: Request):
        """Admission order: effective priority class first (aging boosts
        queued wait — raw priority exactly when ``aging == 0``), earliest
        SLO deadline next, submit order last (FIFO within a class —
        uniform priorities reduce to the seed's queue order exactly)."""
        dl = float("inf") if req.deadline is None else req.deadline
        return (-self._eff_priority(req), dl, req._seq)

    def _candidates(self) -> list:
        """Admissible work, best first.  Swapped requests re-admit ahead of
        everything still queued in their priority class — their submit
        stamp predates it (their pages were taken, not their turn) — but a
        higher class still outranks them, so a parked victim can never
        head-of-line-block the very traffic that preempted it."""
        cands = list(self._swapped.values()) + list(self.queue)
        return sorted(cands, key=lambda c: self._rank(
            c.req if isinstance(c, _Swapped) else c))

    def _admit(self) -> None:
        """Fill slots from the swap space and the queue in priority order:
        prefill each prompt, then install the whole wave's page-aligned KV
        extents through ONE write-network flush (``prefill/*`` streams —
        ``fabric_stats.prefill_bursts``), with the per-layer splice as the
        off-geometry fallback; swap-ins restore eagerly (one ``swap/*``
        flush per slot).  Pool mode gates on free pages (head-of-line
        within the priority order; retirement reclaims) — and when the best
        candidate outranks live work, preempts victims instead of waiting
        (:meth:`_make_room`).  An injected pool-exhaustion fault backs the
        whole wave off for the step."""
        self._shed_unmeetable_queued()
        if (self.kv.paged and self.fault_injector is not None
                and self.fault_injector.pool_exhausted(self._step_count)):
            return
        wave: list = []
        protected: set = set()         # slots filled this wave — not victims
        while True:
            cands = self._candidates()
            if not cands:
                break
            cand = cands[0]
            req = cand.req if isinstance(cand, _Swapped) else cand
            free = [s for s in range(self.max_slots)
                    if self.active[s] is None]
            if self.kv.paged:
                # reserve the request's full reach (prompt + generation,
                # capped by the cache depth) so decode growth can never
                # exhaust the pool mid-flight — admission is the only gate
                reach = min(len(req.prompt) + req.max_new_tokens, self.t_max)
                need = self.kv.table.pages_for(reach)
                if not free or self._pool_headroom() < need:
                    if not self._make_room(req, need, protected,
                                           have_slot=bool(free)):
                        break        # wait for pages to be reclaimed
                    free = [s for s in range(self.max_slots)
                            if self.active[s] is None]
                self._page_reserve[free[0]] = need
            elif not free:
                break
            slot = free[0]
            protected.add(slot)
            self._install(cand, slot, wave)
        if wave:
            self.kv.admit_wave(wave, stats=self.fabric_stats,
                               burst=self.prefill_burst)

    def _install(self, cand, slot: int, wave: list) -> None:
        """Land one candidate in ``slot``: fresh requests prefill into the
        wave; swapped requests either restore over the fabric (swap arm) or
        re-prefill everything decoded so far (recompute arm) — both resume
        the exact pre-eviction state (cache = ``prompt + generated[:-1]``,
        the last token still pending decode)."""
        req0 = cand.req if isinstance(cand, _Swapped) else cand
        if self.aging and self._eff_priority(req0) > req0.priority:
            self.fabric_stats.aging_promotions += 1
        if isinstance(cand, _Swapped):
            req = cand.req
            del self._swapped[req.rid]
            self.active[slot] = req
            self.pos[slot] = cand.pos
            self.tokens[slot, 0] = cand.token
            if cand.record is not None:
                self.kv.swap_in(slot, cand.record, stats=self.fabric_stats)
                self._swap_pages_used -= cand.record.mapped
            else:
                full = np.concatenate([np.asarray(req.prompt, np.int32),
                                       np.asarray(req.generated[:-1],
                                                  np.int32)])
                _, req_cache = api.prefill_fn(
                    self.params, {"tokens": jnp.asarray(full)[None, :]},
                    self.cfg, self.t_alloc)
                wave.append((slot, req_cache, len(full)))
        else:
            req = cand
            self.queue.remove(req)
            prompt = jnp.asarray(req.prompt)[None, :]
            logits, req_cache = api.prefill_fn(
                self.params, {"tokens": prompt}, self.cfg, self.t_alloc)
            # page remap: only the pages the prompt occupies move
            wave.append((slot, req_cache, len(req.prompt)))
            self.active[slot] = req
            self.pos[slot] = len(req.prompt)
            first = int(np.argmax(np.asarray(logits[0, -1])))
            req.generated.append(first)
            self.tokens[slot, 0] = first
            if self.recorder is not None:
                self.recorder.record_first_token(req, self._step_count)
        if self.recorder is not None:
            self.recorder.record_admit(req, self._step_count)
        self._admitted_at[slot] = self._step_count
        # draft branches are a per-tenure cache: a slot changing hands (or
        # a request resuming after eviction) starts with a drained branch
        self._draft_queue.pop(slot, None)

    # -- preemption ----------------------------------------------------------
    def _make_room(self, req: Request, need: int, protected: set,
                   have_slot: bool) -> bool:
        """Evict strictly-lower-priority live slots until ``req`` has a
        slot and ``need`` pages of headroom.  Victim order: lowest priority
        first, then most mapped pages (fewest evictions), then oldest
        admission (LRU).  All-or-nothing: if even every eligible victim
        wouldn't make room, nothing is evicted."""
        if self.preempt == "off":
            return False
        # effective (aged) priorities on both sides: an aged candidate can
        # evict fresher high classes, and once admitted its own growing age
        # shields it from them — without aging this is raw priority exactly
        victims = [s for s in range(self.max_slots)
                   if self.active[s] is not None and s not in protected
                   and (self._eff_priority(self.active[s])
                        < self._eff_priority(req))]
        victims.sort(key=lambda s: (self._eff_priority(self.active[s]),
                                    -self.kv.pool.mapped(s),
                                    self._admitted_at.get(s, 0)))
        headroom = self._pool_headroom()
        chosen = []
        for s in victims:
            if have_slot and headroom >= need:
                break
            # freeing s returns its mapped pages AND retires its reserve
            headroom += max(self.kv.pool.mapped(s),
                            self._page_reserve.get(s, 0))
            have_slot = True
            chosen.append(s)
        if not (have_slot and headroom >= need):
            return False
        for s in chosen:
            self._preempt_slot(s)
        return True

    def _preempt_slot(self, slot: int) -> None:
        """Evict one live slot.  Swap arm: stage its KV image out over the
        fabric (``swap/*`` gather streams) into the host swap space.
        Recompute arm — chosen by config, when the swap-space cap is
        reached, or when nothing has been decoded yet (re-prefilling the
        prompt is the same work with no swap-space cost) — just drops the
        pages."""
        req = self.active[slot]
        use_swap = self.preempt == "swap" and len(req.generated) > 1
        if use_swap and self.swap_space_pages:
            if (self._swap_pages_used + self.kv.pool.mapped(slot)
                    > self.swap_space_pages):
                use_swap = False
        if use_swap:
            record = self.kv.swap_out(slot, stats=self.fabric_stats)
            self._swap_pages_used += record.mapped
        else:
            record = None
            self.kv.free(slot)
        self._swapped[req.rid] = _Swapped(
            req=req, record=record, pos=int(self.pos[slot]),
            token=int(self.tokens[slot, 0]))
        self.active[slot] = None
        self._page_reserve.pop(slot, None)
        self._admitted_at.pop(slot, None)
        self.fabric_stats.preemptions += 1

    def _pool_headroom(self) -> int:
        """Free pages not spoken for by live slots' unexpanded reaches."""
        return self.kv.pool.free_pages - sum(
            max(0, need - self.kv.pool.mapped(s))
            for s, need in self._page_reserve.items())

    # -- one engine step -----------------------------------------------------
    def step(self) -> int:
        """Admit + one batched decode step; returns #active sequences.

        With a fault injector attached, the engine snapshots its full state
        before the step; an injected mid-step failure rolls back to that
        snapshot and replays the step (``fabric_stats.faults_recovered``) —
        the replay is deterministic, so recovery is bit-exact.  With
        ``check_pool`` the free-list conservation invariant runs after
        every step."""
        step_no = self._step_count
        snap = self._snapshot() if self.fault_injector is not None else None
        try:
            n_live = self._step_inner(step_no)
        except RuntimeError:
            if snap is None:
                raise
            self._restore(snap)
            self.fabric_stats.faults_recovered += 1
            n_live = self._step_inner(step_no)
        self._step_count = step_no + 1
        if self.check_pool and self.kv.paged:
            self.kv.pool.check()
        return n_live

    def _step_inner(self, step_no: int) -> int:
        self._admit()
        if self.fault_injector is not None:
            self.fault_injector.check(step_no)     # mid-step failure seam
        live = [s for s in range(self.max_slots) if self.active[s] is not None]
        if not live:
            return 0
        args = (self.params, jnp.asarray(self.tokens), self.kv.caches,
                jnp.asarray(self.pos))
        if self.paged and self.fused:
            live_idx, expand, dense_pos = cm.page_live_plan(
                self.kv.pool.table, self.page_size, self.t_alloc,
                self.fabric.n_ports, bucket=self.live_bucket)
            plan_args = (self.kv.page_table_device(), jnp.asarray(live_idx),
                         jnp.asarray(expand), jnp.asarray(dense_pos))
            if self.pool_shards > 1:
                # host-side split of the live set by owning shard: one
                # fetch/place plan per distinct leaf rep count (the bucket
                # capacity quantizes to whole pages to bound retraces)
                frames = self.kv.pool.n_pages * self.page_size
                plans = {
                    reps: shard_plan(live_idx, frames, self.pool_shards,
                                     self.fabric.n_ports, reps=reps,
                                     cap_bucket=self.page_size).operands()
                    for reps in self._shard_reps}
                logits, new_caches = self._decode(*args, *plan_args, plans)
            else:
                logits, new_caches = self._decode(*args, *plan_args)
        elif self.paged:
            logits, new_caches = self._decode(
                *args, self.kv.page_table_device())
        else:
            logits, new_caches = self._decode(*args)
        self.kv.update(new_caches)
        self.last_logits = logits[:, 0]
        # commits only ever read row 0 — the real unembedding — so the
        # token stream is the dense engine's regardless of spec_decode_k
        nxt = np.asarray(jnp.argmax(logits[:, 0], axis=-1), np.int32)
        drafts = None
        if self._model_draft:
            drafts = np.asarray(
                jnp.argmax(logits[:, 1:1 + self.spec_k], axis=-1), np.int32)
        for s in live:
            req = self.active[s]
            self.pos[s] += 1
            self.kv.extend(s, int(self.pos[s]))
            req.generated.append(int(nxt[s]))
            self.tokens[s, 0] = int(nxt[s])
            if self.spec_k:
                self.verify_step(s, req, int(nxt[s]),
                                 None if drafts is None else drafts[s])
            if (len(req.generated) >= req.max_new_tokens
                    or self.pos[s] + 1 >= self.t_max):
                req.done = True
                if req.deadline is not None and step_no > req.deadline:
                    self.fabric_stats.slo_missed_served += 1
                if self.recorder is not None:
                    self.recorder.record_retire(req, step_no)
                self.active[s] = None
                # return the slot's pages (true reclamation in pool mode);
                # stale frames are masked by the per-slot positions and
                # overwritten on the next admission
                self.kv.free(s)
                self._page_reserve.pop(s, None)
                self._admitted_at.pop(s, None)
                self._draft_queue.pop(s, None)
        return len([s for s in range(self.max_slots)
                    if self.active[s] is not None])

    # -- speculative decoding -------------------------------------------------
    def verify_step(self, slot: int, req: Request, committed: int,
                    drafts) -> None:
        """Verify one level of the slot's draft branch against the target's
        committed token (longest-matching-prefix acceptance, unrolled one
        token per engine step).  The slot's candidate branch prefix rides
        the step's existing fused page-table gather — all k candidates
        share the committed prefix, so the ``gather=`` streams that banked
        the slot's live frames for the target ARE the branch gather; no new
        kernel, and the census's per-step ``words_live`` is the gathered
        branch traffic.  A match pops the branch head
        (``spec_accepted``); a mismatch discards the remaining branch
        (``spec_rejected`` — the committed argmax is itself the correction
        token, so nothing needs re-decoding); a drained branch takes on k
        fresh proposals from the draft heads (or ``draft_fn``)."""
        q = self._draft_queue.get(slot)
        if q:
            if q[0] == committed:
                self.spec_accepted += 1
                q.pop(0)
            else:
                self.spec_rejected += len(q)
                q.clear()
        if not self._draft_queue.get(slot):
            if self.draft_fn is not None:
                prop = self.draft_fn(req, committed)
            else:
                prop = [] if drafts is None else [int(x) for x in drafts]
            prop = list(prop)[:self.spec_k]
            if prop:
                self._draft_queue[slot] = prop
                self.spec_proposed += len(prop)

    @property
    def spec_acceptance(self) -> float:
        """Fraction of proposed draft tokens the target verified."""
        return self.spec_accepted / max(1, self.spec_proposed)

    @property
    def step_count(self) -> int:
        """Engine steps taken so far — the clock every lifecycle stamp,
        deadline and aging computation is measured in."""
        return self._step_count

    @property
    def drained(self) -> bool:
        """No live, queued or parked work left."""
        return (not self.queue and not self._swapped
                and all(r is None for r in self.active))

    @property
    def slo_misses(self) -> int:
        """Total deadline misses across every exit path: late retirements
        (``slo_missed_served``) plus deadlined requests shed at admission
        or from the queue (``slo_missed_shed``).  The pre-harness counter
        only saw the first kind."""
        return (self.fabric_stats.slo_missed_served
                + self.fabric_stats.slo_missed_shed)

    def pending_census(self) -> str:
        """Why-can't-anything-advance diagnosis: per-class queue depths
        over live, queued and parked work, pool headroom, and swap-space
        occupancy — the stall story ``run_to_completion`` raises with."""
        def by_class(reqs):
            depth: Dict[int, int] = {}
            for r in reqs:
                depth[r.priority] = depth.get(r.priority, 0) + 1
            return ("{" + ", ".join(f"class{p}: {n}" for p, n in
                                    sorted(depth.items())) + "}"
                    if depth else "{}")
        live = [r for r in self.active if r is not None]
        parked = [w.req for w in self._swapped.values()]
        pool = (f"pool headroom {self._pool_headroom()} of "
                f"{self.kv.pool.n_pages} pages "
                f"({self.kv.pool.free_pages} free)" if self.kv.paged
                else "pool off (dense reservation)")
        cap = self.swap_space_pages or "unbounded"
        return (f"live {by_class(live)}, queued {by_class(self.queue)}, "
                f"swapped {by_class(parked)}; {pool}; "
                f"swap space {self._swap_pages_used} pages used (cap {cap})")

    def run_to_completion(self, max_steps: int = 10_000) -> None:
        """Step until every submitted request retires.  Raises — rather
        than silently returning with work stranded — when ``max_steps``
        runs out first, naming per-class queue depths, pool headroom and
        swap occupancy so the stall is diagnosable."""
        for _ in range(max_steps):
            if self.step() == 0 and not self.queue and not self._swapped:
                return
        pending = (sum(r is not None for r in self.active) + len(self.queue)
                   + len(self._swapped))
        raise RuntimeError(
            f"run_to_completion: {max_steps} steps exhausted with {pending} "
            f"requests still pending — {self.pending_census()} — the "
            f"workload does not fit, or admission is starved")

    # -- fault recovery ------------------------------------------------------
    def _snapshot(self) -> dict:
        """The engine's full pre-step state.  Device arrays are immutable
        (the cache pytree is captured by reference); host state — request
        bookkeeping, page table, free lists, counters — is copied.  Request
        objects are shared with the caller, so only their mutable tail
        (``generated`` length, ``done``) is recorded."""
        reqs = [(r, len(r.generated), r.done) for r in
                (list(self.queue) + [w.req for w in self._swapped.values()]
                 + [r for r in self.active if r is not None])]
        pool = self.kv.pool
        return dict(
            caches=self.kv.caches,
            pos=self.pos.copy(), tokens=self.tokens.copy(),
            active=list(self.active), queue=list(self.queue),
            swapped=dict(self._swapped),
            reserve=dict(self._page_reserve),
            admitted=dict(self._admitted_at),
            swap_used=self._swap_pages_used,
            submit_seq=self._submit_seq,
            last_logits=self.last_logits,
            table_used=self.kv.table.used.copy(),
            dirty=self.kv._dirty.copy(),
            kv_counters=(self.kv.tokens_moved, self.kv.tokens_moved_dense,
                         self.kv.prefill_bursts, self.kv.prefill_splices),
            pool=None if pool is None else (
                pool.table.copy(),
                [list(s) for s in pool._free_by_shard], pool._rr,
                pool.pages_allocated, pool.pages_reclaimed,
                pool.pages_swapped_out, pool.pages_swapped_in),
            stats=dataclasses.replace(self.fabric_stats),
            spec=(self.spec_proposed, self.spec_accepted, self.spec_rejected,
                  {s: list(q) for s, q in self._draft_queue.items()}),
            reqs=reqs)

    def _restore(self, snap: dict) -> None:
        """Roll back to the pre-step snapshot (restore-from-last-consistent-
        state).  ``fabric_stats`` is restored field-in-place — the jitted
        step closed over the instance, so its identity must survive."""
        self.kv.update(snap["caches"])
        self.pos[:] = snap["pos"]
        self.tokens[:] = snap["tokens"]
        self.active = snap["active"]
        self.queue = snap["queue"]
        self._swapped = snap["swapped"]
        self._page_reserve = snap["reserve"]
        self._admitted_at = snap["admitted"]
        self._swap_pages_used = snap["swap_used"]
        self._submit_seq = snap["submit_seq"]
        self.last_logits = snap["last_logits"]
        self.kv.table.used[:] = snap["table_used"]
        self.kv._dirty[:] = snap["dirty"]
        (self.kv.tokens_moved, self.kv.tokens_moved_dense,
         self.kv.prefill_bursts, self.kv.prefill_splices) = snap["kv_counters"]
        if snap["pool"] is not None:
            pool = self.kv.pool
            (table, free, rr, alloc, reclaimed, s_out, s_in) = snap["pool"]
            pool.table[:] = table
            pool._free_by_shard = [list(s) for s in free]
            pool._rr = rr
            pool.pages_allocated = alloc
            pool.pages_reclaimed = reclaimed
            pool.pages_swapped_out = s_out
            pool.pages_swapped_in = s_in
        for f in dataclasses.fields(SchedulerStats):
            setattr(self.fabric_stats, f.name, getattr(snap["stats"], f.name))
        (self.spec_proposed, self.spec_accepted, self.spec_rejected,
         queues) = snap["spec"]
        self._draft_queue = {s: list(q) for s, q in queues.items()}
        for r, n_gen, done in snap["reqs"]:
            del r.generated[n_gen:]
            r.done = done
