"""Slot-based continuous-batching serving engine on the paged KV layout.

A fixed decode batch of ``max_slots`` sequences advances one token per step;
finished sequences retire and their slots are immediately refilled from the
queue.  KV storage goes through :class:`repro.fabric.PagedKVCache`: each
slot's time axis is divided into fixed-size pages (``page_size`` timesteps =
a burst of lines through the fabric).  Under ``FabricConfig.paged_pool``
(the default) the pages live in one **shared physical pool** per
full-attention leaf — free-list allocation at admission and decode growth,
true reclamation at retirement, per-slot logical→physical page table as a
decode-step operand (gather-based attention) — so short and long sequences
share HBM and ``kv.occupancy`` measures real frames.  Admission installs
each wave's page-aligned KV extents through one ``prefill/*`` write burst
(1 network call per dtype; per-layer splice as the off-geometry fallback)
instead of the seed engine's full ``t_max`` splice-copy.  Per-slot
positions are first-class in the decode
path (``models.common._cache_write`` and friends), so slots at different
depths coexist in one batched step — the production pattern behind
vLLM-style serving, on top of the Medusa KV layout engine
(``cfg.resolved_fabric``).

The decode step is the burst scheduler's first production consumer: a
:class:`repro.fabric.BurstScheduler` instance per step hoists every
full-attention leaf's port-major conversion into one shared read burst,
runs attention in port-major space, and restores line-major caches through
one write burst — 1 read + 1 write network invocation per dtype per step
(``fabric_stats``), with the ``serve_fsdp`` weight stream riding the same
read burst.  Bit-identical to the per-layer path.  The bursts ride the
fabric's machine-word lane folding (``FabricConfig.word_fold``) and, on the
medusa fabric with kernels enabled, lower as one fused Pallas launch per
direction per dtype (``fabric_stats.words_folded`` / ``.kernel_bursts``).

Under the **fused-gather contract** (``FabricConfig.fused_gather``, auto-on
with the pool) the pool's logical→physical indirection moves into those
bursts: each engine step plans its live frames host-side
(:func:`repro.models.common.page_live_plan`, bucketed to bound retraces)
and the KV streams become sparse-extent — the networks bank only the
frames the page table maps, so decode traffic scales with live tokens
instead of pool capacity (``fabric_stats.words_live`` /
``.gather_fused_bursts``); the gather-after-burst form stays as the
fallback (``fused_gather=False``) and the bit-parity reference.

Decoder-only families (dense/moe/ssm/hybrid/vlm); greedy sampling.
"""

from __future__ import annotations

import dataclasses
import math
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.fabric import (BurstScheduler, Fabric, PagedKVCache,
                          SchedulerStats, make_pool_mesh, shard_plan)
from repro.models import api
from repro.models import common as cm
from repro.models import lm


def _lead_prod(flat) -> int:
    """Product of a flattened pool leaf's leading (layer-stack) axes."""
    reps = 1
    for s in flat.shape[:-3]:
        reps *= s
    return reps


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray                     # [prompt_len] int32
    max_new_tokens: int
    generated: list = dataclasses.field(default_factory=list)
    done: bool = False


class ServingEngine:
    def __init__(self, cfg: ModelConfig, params, max_slots: int, t_max: int,
                 page_size: int = 0, paged_pool: Optional[bool] = None,
                 pool_pages: int = 0, prefill_burst: Optional[bool] = None,
                 fused_gather: Optional[bool] = None, pool_shards: int = 0,
                 collective: Optional[str] = None):
        assert cfg.family != "audio", "engine covers decoder-only families"
        self.cfg = cfg
        self.params = params
        self.max_slots = max_slots
        self.t_max = t_max
        # pool-sharded lowering (FabricConfig.pool_shards): the pool axis of
        # every full-attention leaf shards over a `pool` device-mesh axis
        # and the fused sparse bursts become per-shard gathers bridged by
        # one collective (repro.fabric.sharded) — the engine runs unchanged
        # otherwise; 0 inherits the model config's setting
        fab_cfg = cfg.resolved_fabric
        shards = pool_shards or fab_cfg.pool_shards
        if shards > 1 or collective is not None:
            fab_cfg = dataclasses.replace(
                fab_cfg, pool_shards=shards,
                collective=collective or fab_cfg.collective).validate()
        self.pool_shards = shards = fab_cfg.pool_shards
        mesh = make_pool_mesh(shards) if shards > 1 else None
        self.fabric = Fabric(fab_cfg, mesh=mesh)
        # cache depth rounds up so every full-attention leaf's line count
        # divides N and the whole cache moves through the step's shared
        # burst; positions beyond t_max are masked, so this is free capacity
        n = self.fabric.n_ports
        self.t_alloc = -(-t_max // n) * n
        ps = page_size or min(cfg.resolved_fabric.page_size, self.t_alloc)
        self.page_size = ps
        # shared physical page pool (FabricConfig.paged_pool, default on):
        # full-attention leaves become [pool_pages, ps, Hkv, D] regions
        # reached through the per-slot page table; families without
        # full-attention leaves (pure SSM/recurrent) have nothing to pool
        entries = lm.paged_entries(cfg)
        self.paged = ((cfg.resolved_fabric.paged_pool if paged_pool is None
                       else paged_pool) and bool(entries))
        if self.paged:
            pages_per_slot = -(-self.t_alloc // ps)
            pool_pages = pool_pages or max_slots * pages_per_slot
            # the pool rides the decode step's shared burst as one line
            # stream, so its frame count rounds up to a multiple of N; under
            # the sharded lowering it must also split into `shards` equal
            # contiguous page blocks (PartitionSpec("pool") ownership)
            while (pool_pages * ps) % n or pool_pages % shards:
                pool_pages += 1
        else:
            pool_pages = 0
        self.prefill_burst = prefill_burst
        # fused page-table gather (FabricConfig.fused_gather, default on
        # with the pool): the decode step's bursts bank only the frames the
        # page table maps — the engine plans the live set host-side each
        # step and passes it as operands, so network traffic scales with
        # live tokens instead of pool capacity.  Needs a fabric that banks
        # KV at all; the gather-after-burst form stays as the fallback.
        self.fused = ((cfg.resolved_fabric.fused_gather_on
                       if fused_gather is None else fused_gather)
                      and self.paged and self.fabric.banks_kv)
        if shards > 1 and not self.fused:
            raise ValueError(
                f"pool_shards={shards} needs the fused-gather pool contract "
                f"(paged pool + a fabric that banks KV) — the sharded "
                f"lowering is the sparse burst's collective form")
        # live-plan lengths quantize to whole page-of-lines buckets so the
        # jitted step retraces per occupancy *bucket*, not per page; sharded,
        # the bucket also keeps every rep's line total divisible into
        # `shards` blocks of whole N-groups (lcm, so 1 shard is unchanged)
        self.live_bucket = n * math.lcm(ps, shards)
        self.kv = PagedKVCache(
            api.init_cache(cfg, max_slots, self.t_alloc,
                           pool_pages=pool_pages, page_size=ps),
            max_slots, self.t_alloc, ps, pool_pages=pool_pages,
            paged_entries=entries if self.paged else (), fabric=self.fabric,
            fused_gather=self.fused, pool_shards=shards)
        # distinct leading rep counts over the paged leaves — the sharded
        # step carries one (fetch, place) plan per rep count
        self._shard_reps = sorted({
            max(1, _lead_prod(lm._flat_frames(self.kv.caches[kind][i]["k"])))
            for kind, i in entries}) if (self.paged and shards > 1) else []
        self.pos = np.zeros((max_slots,), np.int32)      # next write position
        self.active: List[Optional[Request]] = [None] * max_slots
        self.tokens = np.zeros((max_slots, 1), np.int32)
        self.queue: List[Request] = []
        # the step's [B, V] logits, left on device (readers pay the copy)
        self.last_logits: Optional[jax.Array] = None
        # pool mode: pages reserved per live slot for its full reach
        # (prompt + generation) — admission is the only allocation gate, so
        # decode growth can never exhaust the pool mid-flight
        self._page_reserve: dict = {}

        # one scheduler instance per decode step: per-step KV banking (and
        # the serve_fsdp weight stream) runs as one read + one write network
        # burst per dtype.  ``fabric_stats`` accumulates at trace time, so
        # after the first step it reads as the per-step traffic census
        # (plus one eager prefill burst per admission wave).
        self.fabric_stats = SchedulerStats()

        if self.paged and self.fused and shards > 1:
            def _step(p, tok, caches, pos, page_table, live_idx, expand,
                      dense_pos, shard_plans):
                sched = BurstScheduler(self.fabric, stats=self.fabric_stats)
                return api.decode_fn(p, tok, caches, pos, cfg, sched=sched,
                                     page_table=page_table, page_size=ps,
                                     t_depth=self.t_alloc,
                                     live_plan=(live_idx, expand, dense_pos),
                                     shard_plans=shard_plans)
        elif self.paged and self.fused:
            def _step(p, tok, caches, pos, page_table, live_idx, expand,
                      dense_pos):
                sched = BurstScheduler(self.fabric, stats=self.fabric_stats)
                return api.decode_fn(p, tok, caches, pos, cfg, sched=sched,
                                     page_table=page_table, page_size=ps,
                                     t_depth=self.t_alloc,
                                     live_plan=(live_idx, expand, dense_pos))
        elif self.paged:
            def _step(p, tok, caches, pos, page_table):
                sched = BurstScheduler(self.fabric, stats=self.fabric_stats)
                return api.decode_fn(p, tok, caches, pos, cfg, sched=sched,
                                     page_table=page_table, page_size=ps,
                                     t_depth=self.t_alloc)
        else:
            def _step(p, tok, caches, pos):
                sched = BurstScheduler(self.fabric, stats=self.fabric_stats)
                return api.decode_fn(p, tok, caches, pos, cfg, sched=sched)

        self._decode = jax.jit(_step)

    @property
    def caches(self):
        """The batched cache pytree (lives inside the paged wrapper)."""
        return self.kv.caches

    # -- admission -----------------------------------------------------------
    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def _admit(self) -> None:
        """Fill free slots from the queue: prefill each prompt, then install
        the whole wave's page-aligned KV extents through ONE write-network
        flush (``prefill/*`` streams — ``fabric_stats.prefill_bursts``),
        with the per-layer splice as the off-geometry fallback.  Pool mode
        gates admission on free pages (head-of-line; retirement reclaims)."""
        wave = []
        for slot in range(self.max_slots):
            if self.active[slot] is not None or not self.queue:
                continue
            if self.kv.paged:
                # reserve the request's full reach (prompt + generation,
                # capped by the cache depth) so decode growth can never
                # exhaust the pool mid-flight — admission is the only gate
                nxt = self.queue[0]
                reach = min(len(nxt.prompt) + nxt.max_new_tokens, self.t_max)
                need = self.kv.table.pages_for(reach)
                if self._pool_headroom() < need:
                    break                # wait for pages to be reclaimed
                self._page_reserve[slot] = need
            req = self.queue.pop(0)
            prompt = jnp.asarray(req.prompt)[None, :]
            logits, req_cache = api.prefill_fn(
                self.params, {"tokens": prompt}, self.cfg, self.t_alloc)
            # page remap: only the pages the prompt occupies move
            wave.append((slot, req_cache, len(req.prompt)))
            self.active[slot] = req
            self.pos[slot] = len(req.prompt)
            first = int(np.argmax(np.asarray(logits[0, -1])))
            req.generated.append(first)
            self.tokens[slot, 0] = first
        if wave:
            self.kv.admit_wave(wave, stats=self.fabric_stats,
                               burst=self.prefill_burst)

    def _pool_headroom(self) -> int:
        """Free pages not spoken for by live slots' unexpanded reaches."""
        return self.kv.pool.free_pages - sum(
            max(0, need - self.kv.pool.mapped(s))
            for s, need in self._page_reserve.items())

    # -- one engine step -----------------------------------------------------
    def step(self) -> int:
        """Admit + one batched decode step; returns #active sequences."""
        self._admit()
        live = [s for s in range(self.max_slots) if self.active[s] is not None]
        if not live:
            return 0
        args = (self.params, jnp.asarray(self.tokens), self.kv.caches,
                jnp.asarray(self.pos))
        if self.paged and self.fused:
            live_idx, expand, dense_pos = cm.page_live_plan(
                self.kv.pool.table, self.page_size, self.t_alloc,
                self.fabric.n_ports, bucket=self.live_bucket)
            plan_args = (self.kv.page_table_device(), jnp.asarray(live_idx),
                         jnp.asarray(expand), jnp.asarray(dense_pos))
            if self.pool_shards > 1:
                # host-side split of the live set by owning shard: one
                # fetch/place plan per distinct leaf rep count (the bucket
                # capacity quantizes to whole pages to bound retraces)
                frames = self.kv.pool.n_pages * self.page_size
                plans = {
                    reps: shard_plan(live_idx, frames, self.pool_shards,
                                     self.fabric.n_ports, reps=reps,
                                     cap_bucket=self.page_size).operands()
                    for reps in self._shard_reps}
                logits, new_caches = self._decode(*args, *plan_args, plans)
            else:
                logits, new_caches = self._decode(*args, *plan_args)
        elif self.paged:
            logits, new_caches = self._decode(
                *args, self.kv.page_table_device())
        else:
            logits, new_caches = self._decode(*args)
        self.kv.update(new_caches)
        self.last_logits = logits[:, 0]
        nxt = np.asarray(jnp.argmax(logits[:, 0], axis=-1), np.int32)
        for s in live:
            req = self.active[s]
            self.pos[s] += 1
            self.kv.extend(s, int(self.pos[s]))
            req.generated.append(int(nxt[s]))
            self.tokens[s, 0] = int(nxt[s])
            if (len(req.generated) >= req.max_new_tokens
                    or self.pos[s] + 1 >= self.t_max):
                req.done = True
                self.active[s] = None
                # return the slot's pages (true reclamation in pool mode);
                # stale frames are masked by the per-slot positions and
                # overwritten on the next admission
                self.kv.free(s)
                self._page_reserve.pop(s, None)
        return len([s for s in range(self.max_slots)
                    if self.active[s] is not None])

    def run_to_completion(self, max_steps: int = 10_000) -> None:
        for _ in range(max_steps):
            if self.step() == 0 and not self.queue:
                return
