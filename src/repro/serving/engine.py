"""Slot-based continuous-batching serving engine on the paged KV layout.

A fixed decode batch of ``max_slots`` sequences advances one token per step;
finished sequences retire and their slots are immediately refilled from the
queue.  KV storage goes through :class:`repro.fabric.PagedKVCache`: each
slot's time axis is divided into fixed-size pages (``page_size`` timesteps =
a burst of lines through the fabric), and admission writes only the pages
the new prompt occupies — a page remap instead of the seed engine's full
``t_max`` splice-copy.  Per-slot positions are first-class in the decode
path (``models.common._cache_write`` and friends), so slots at different
depths coexist in one batched step — the production pattern behind
vLLM-style serving, on top of the Medusa KV layout engine
(``cfg.resolved_fabric``).

Decoder-only families (dense/moe/ssm/hybrid/vlm); greedy sampling.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.fabric import PagedKVCache
from repro.models import api
from repro.models import lm


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray                     # [prompt_len] int32
    max_new_tokens: int
    generated: list = dataclasses.field(default_factory=list)
    done: bool = False


class ServingEngine:
    def __init__(self, cfg: ModelConfig, params, max_slots: int, t_max: int,
                 page_size: int = 0):
        assert cfg.family != "audio", "engine covers decoder-only families"
        self.cfg = cfg
        self.params = params
        self.max_slots = max_slots
        self.t_max = t_max
        self.kv = PagedKVCache(
            api.init_cache(cfg, max_slots, t_max), max_slots, t_max,
            page_size or min(cfg.resolved_fabric.page_size, t_max))
        self.pos = np.zeros((max_slots,), np.int32)      # next write position
        self.active: List[Optional[Request]] = [None] * max_slots
        self.tokens = np.zeros((max_slots, 1), np.int32)
        self.queue: List[Request] = []

        self._decode = jax.jit(
            lambda p, tok, caches, pos: api.decode_fn(p, tok, caches, pos, cfg))

    @property
    def caches(self):
        """The batched cache pytree (lives inside the paged wrapper)."""
        return self.kv.caches

    # -- admission -----------------------------------------------------------
    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def _admit(self) -> None:
        for slot in range(self.max_slots):
            if self.active[slot] is not None or not self.queue:
                continue
            req = self.queue.pop(0)
            prompt = jnp.asarray(req.prompt)[None, :]
            logits, req_cache = api.prefill_fn(
                self.params, {"tokens": prompt}, self.cfg, self.t_max)
            # page remap: only the pages the prompt occupies move
            self.kv.refill(slot, req_cache, len(req.prompt))
            self.active[slot] = req
            self.pos[slot] = len(req.prompt)
            first = int(np.argmax(np.asarray(logits[0, -1])))
            req.generated.append(first)
            self.tokens[slot, 0] = first

    # -- one engine step -----------------------------------------------------
    def step(self) -> int:
        """Admit + one batched decode step; returns #active sequences."""
        self._admit()
        live = [s for s in range(self.max_slots) if self.active[s] is not None]
        if not live:
            return 0
        logits, new_caches = self._decode(
            self.params, jnp.asarray(self.tokens), self.kv.caches,
            jnp.asarray(self.pos))
        self.kv.update(new_caches)
        nxt = np.asarray(jnp.argmax(logits[:, 0], axis=-1), np.int32)
        for s in live:
            req = self.active[s]
            self.pos[s] += 1
            self.kv.extend(s, int(self.pos[s]))
            req.generated.append(int(nxt[s]))
            self.tokens[s, 0] = int(nxt[s])
            if (len(req.generated) >= req.max_new_tokens
                    or self.pos[s] + 1 >= self.t_max):
                req.done = True
                self.active[s] = None
                # return the slot's pages; stale frames are masked by the
                # per-slot positions and overwritten on the next admission
                self.kv.free(s)
        return len([s for s in range(self.max_slots)
                    if self.active[s] is not None])

    def run_to_completion(self, max_steps: int = 10_000) -> None:
        for _ in range(max_steps):
            if self.step() == 0 and not self.queue:
                return
