"""Production-shaped traffic harness for the serving engine.

The paper's premise is a many-narrow-ports → one-wide-bus mismatch under
*sustained demand* (PAPER.md §I); everything below the engine now rides
that fabric, and this module proves the scheduling layer above it degrades
gracefully when demand exceeds the pool.  Three pieces:

* **Seeded load generator** (:class:`TrafficConfig` →
  :func:`generate_trace`): Poisson or bursty-diurnal arrivals, heavy-tailed
  lognormal prompt/generation lengths, a weighted priority-class mix, and
  an SLO-deadline mix — emitted as replayable :class:`TraceRecord` rows
  (JSON round-trippable via :func:`save_trace`/:func:`load_trace`), so a
  run can be replayed bit-exactly, with or without fault injection.

* **:class:`MetricsRecorder`**: stamps every request's lifecycle in engine
  steps — submit → first admit → first token → retire or shed — and
  reports per-class TTFT / TPOT / queue-wait percentiles, goodput and the
  shed/SLO census alongside the engine's ``SchedulerStats``.  The engine
  calls the ``record_*`` hooks itself (``ServingEngine(recorder=...)``);
  stamps are first-write-wins, so a fault-replayed step never
  double-counts.

* **:class:`ReplicaRouter`**: an in-process N-replica fleet behind a
  least-loaded router — the single-host step toward the k8s fleet.  Each
  replica is a full :class:`~repro.serving.engine.ServingEngine`;
  ``submit`` routes to the replica with the least outstanding work
  (queued + live + parked requests, then live tokens, then index — fully
  deterministic), ``step`` advances all replicas in lockstep.

:func:`drive` replays a trace against one engine or a router;
:func:`fault_soak` runs the same seeded trace fault-free and under a
:class:`~repro.runtime.fault_tolerance.FaultInjector`, asserting the two
runs converge token-exact with zero page leaks (``PagePool.check()`` at
drain).  ``python -m repro.launch.loadgen`` is the CLI on top.
"""

from __future__ import annotations

import dataclasses
import json
import math
from typing import Dict, List, Optional, Sequence, Union

import numpy as np

from repro.serving.engine import Request, ServingEngine


# ---------------------------------------------------------------------------
# trace generation
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class TrafficConfig:
    """Knobs for one seeded, replayable traffic trace.

    Arrivals: ``"poisson"`` draws per-step arrival counts at a flat
    ``rate``; ``"diurnal"`` modulates the rate sinusoidally over
    ``diurnal_period`` steps (depth ``diurnal_amp``) and opens multi-step
    burst windows (``burst_prob`` per step, ``burst_mult`` × rate for
    ``burst_len`` steps) — the bursty-diurnal ramp of production serving.

    Lengths: prompt and generation lengths are lognormal (heavy-tailed —
    a few giants among many small requests), clipped to
    ``[prompt_min, prompt_max]`` / ``[gen_min, gen_max]``.

    Classes: request priorities draw from ``class_weights`` over
    ``0..classes-1`` (default: geometric favouring the lowest class, the
    production shape where bulk traffic is cheap and latency-sensitive
    traffic is rare).

    Deadlines: a ``deadline_frac`` fraction of requests carry an SLO
    deadline ``arrival + ceil(deadline_slack * (max_new_tokens + 2))`` —
    slack 1.0 is the tightest meetable bound (one committed token per
    engine step plus admission), below 1.0 requests are born provably
    unmeetable and must be shed up front.
    """

    seed: int = 0
    n_requests: int = 32
    arrival: str = "poisson"               # "poisson" | "diurnal"
    rate: float = 0.5                      # mean arrivals per engine step
    diurnal_period: int = 64
    diurnal_amp: float = 0.8
    burst_prob: float = 0.05
    burst_mult: float = 4.0
    burst_len: int = 4
    prompt_mean: float = 10.0
    prompt_sigma: float = 0.6
    prompt_min: int = 2
    prompt_max: int = 48
    gen_mean: float = 8.0
    gen_sigma: float = 0.7
    gen_min: int = 2
    gen_max: int = 32
    classes: int = 3
    class_weights: Optional[Sequence[float]] = None
    deadline_frac: float = 0.0
    deadline_slack: float = 3.0
    vocab: int = 256

    def validate(self) -> "TrafficConfig":
        if self.arrival not in ("poisson", "diurnal"):
            raise ValueError(f"arrival must be 'poisson' or 'diurnal', "
                             f"got {self.arrival!r}")
        if self.classes < 1:
            raise ValueError(f"need >= 1 priority class, got {self.classes}")
        if self.class_weights is not None \
                and len(self.class_weights) != self.classes:
            raise ValueError(
                f"class_weights has {len(self.class_weights)} entries for "
                f"{self.classes} classes")
        if not 0.0 <= self.deadline_frac <= 1.0:
            raise ValueError(f"deadline_frac must be in [0, 1], got "
                             f"{self.deadline_frac}")
        return self


@dataclasses.dataclass
class TraceRecord:
    """One replayable request: everything :class:`Request` needs plus the
    arrival step the driver submits it at."""

    rid: int
    arrival_step: int
    prompt: np.ndarray                     # [prompt_len] int32
    max_new_tokens: int
    priority: int = 0
    deadline: Optional[int] = None

    def to_request(self) -> Request:
        return Request(self.rid, np.asarray(self.prompt, np.int32).copy(),
                       max_new_tokens=self.max_new_tokens,
                       priority=self.priority, deadline=self.deadline)

    def to_json(self) -> dict:
        return {"rid": self.rid, "arrival_step": self.arrival_step,
                "prompt": np.asarray(self.prompt).tolist(),
                "max_new_tokens": self.max_new_tokens,
                "priority": self.priority, "deadline": self.deadline}

    @staticmethod
    def from_json(d: dict) -> "TraceRecord":
        return TraceRecord(d["rid"], d["arrival_step"],
                           np.asarray(d["prompt"], np.int32),
                           d["max_new_tokens"], d.get("priority", 0),
                           d.get("deadline"))


def _clipped_lognormal(rng: np.random.Generator, mean: float, sigma: float,
                       lo: int, hi: int) -> int:
    """Heavy-tailed integer length: lognormal with median ``mean``, clipped
    into ``[lo, hi]`` (the clip keeps the tail real but servable)."""
    x = rng.lognormal(mean=math.log(max(mean, 1.0)), sigma=sigma)
    return int(min(max(round(x), lo), hi))


def _arrival_rate(cfg: TrafficConfig, step: int, burst_left: int) -> float:
    rate = cfg.rate
    if cfg.arrival == "diurnal":
        rate *= 1.0 + cfg.diurnal_amp * math.sin(
            2.0 * math.pi * step / max(cfg.diurnal_period, 1))
        if burst_left > 0:
            rate *= cfg.burst_mult
    return max(rate, 0.0)


def generate_trace(cfg: TrafficConfig) -> List[TraceRecord]:
    """The seeded generator: same config → bit-identical trace (lengths,
    tokens, arrivals, classes and deadlines all draw from one
    ``np.random.default_rng(seed)`` stream in a fixed order)."""
    cfg.validate()
    rng = np.random.default_rng(cfg.seed)
    weights = cfg.class_weights
    if weights is None:
        weights = [2.0 ** -c for c in range(cfg.classes)]
    w = np.asarray(weights, np.float64)
    w = w / w.sum()
    trace: List[TraceRecord] = []
    step, burst_left = 0, 0
    while len(trace) < cfg.n_requests:
        if cfg.arrival == "diurnal":
            if burst_left > 0:
                burst_left -= 1
            elif rng.random() < cfg.burst_prob:
                burst_left = cfg.burst_len
        n = int(rng.poisson(_arrival_rate(cfg, step, burst_left)))
        for _ in range(min(n, cfg.n_requests - len(trace))):
            rid = len(trace)
            p_len = _clipped_lognormal(rng, cfg.prompt_mean, cfg.prompt_sigma,
                                       cfg.prompt_min, cfg.prompt_max)
            g_len = _clipped_lognormal(rng, cfg.gen_mean, cfg.gen_sigma,
                                       cfg.gen_min, cfg.gen_max)
            prompt = rng.integers(0, cfg.vocab, size=p_len, dtype=np.int32)
            priority = int(rng.choice(cfg.classes, p=w))
            deadline = None
            if rng.random() < cfg.deadline_frac:
                deadline = step + int(
                    math.ceil(cfg.deadline_slack * (g_len + 2)))
            trace.append(TraceRecord(rid, step, prompt, g_len, priority,
                                     deadline))
        step += 1
    return trace


def trace_t_max(trace: Sequence[TraceRecord], pad: int = 1) -> int:
    """The cache depth this trace needs: the widest prompt + generation
    reach, plus ``pad`` (the decode loop writes one position past the last
    committed token)."""
    return max(len(t.prompt) + t.max_new_tokens for t in trace) + pad


def save_trace(path: str, trace: Sequence[TraceRecord]) -> None:
    with open(path, "w") as f:
        json.dump([t.to_json() for t in trace], f)


def load_trace(path: str) -> List[TraceRecord]:
    with open(path) as f:
        return [TraceRecord.from_json(d) for d in json.load(f)]


# ---------------------------------------------------------------------------
# lifecycle metrics
# ---------------------------------------------------------------------------

_PCTS = (50, 90, 99)


def _pcts(xs: List[float]) -> Dict[str, float]:
    if not xs:
        return {f"p{p}": None for p in _PCTS}
    arr = np.asarray(xs, np.float64)
    return {f"p{p}": float(np.percentile(arr, p)) for p in _PCTS}


class MetricsRecorder:
    """Per-request lifecycle stamps, in engine steps.

    The engine calls :meth:`record_admit` / :meth:`record_first_token` /
    :meth:`record_retire` / :meth:`record_shed`; the driver calls
    :meth:`record_submit`.  All stamps are first-write-wins (``retire`` and
    ``shed`` excepted — they are terminal and idempotent under the fault
    injector's deterministic replay), so preemption/re-admission keeps the
    FIRST admit and first token, which is what TTFT means.
    """

    def __init__(self):
        self._rec: Dict[int, dict] = {}
        self.requests: Dict[int, Request] = {}   # filled by drive()

    def _entry(self, req: Request) -> dict:
        return self._rec.setdefault(req.rid, {
            "priority": req.priority, "deadline": req.deadline,
            "prompt_len": len(req.prompt),
            "max_new_tokens": req.max_new_tokens,
            "submit": None, "admit": None, "first_token": None,
            "retire": None, "shed": None, "shed_reason": None,
            "tokens": 0})

    def record_submit(self, req: Request, step: int) -> None:
        e = self._entry(req)
        if e["submit"] is None:
            e["submit"] = step

    def record_admit(self, req: Request, step: int) -> None:
        e = self._entry(req)
        if e["admit"] is None:
            e["admit"] = step

    def record_first_token(self, req: Request, step: int) -> None:
        e = self._entry(req)
        if e["first_token"] is None:
            e["first_token"] = step

    def record_retire(self, req: Request, step: int) -> None:
        e = self._entry(req)
        e["retire"] = step
        e["tokens"] = len(req.generated)

    def record_shed(self, req: Request, step: int, reason: str) -> None:
        e = self._entry(req)
        e["shed"] = step
        e["shed_reason"] = reason

    # -- reporting -----------------------------------------------------------
    def report(self) -> dict:
        """Per-class and aggregate metrics.  TTFT = first token − submit;
        queue wait = first admit − submit; TPOT = decode steps per
        committed token after the first; goodput = requests served within
        their deadline (no-deadline requests count as on time when served)
        over requests submitted."""
        classes: Dict[int, dict] = {}
        for e in self._rec.values():
            by = classes.setdefault(e["priority"], {
                "n": 0, "served": 0, "shed": 0, "tokens": 0, "on_time": 0,
                "slo_missed_served": 0, "slo_missed_shed": 0,
                "ttft": [], "wait": [], "tpot": []})
            by["n"] += 1
            if e["retire"] is not None:
                by["served"] += 1
                by["tokens"] += e["tokens"]
                late = (e["deadline"] is not None
                        and e["retire"] > e["deadline"])
                by["slo_missed_served"] += int(late)
                by["on_time"] += int(not late)
                if e["submit"] is not None and e["first_token"] is not None:
                    by["ttft"].append(e["first_token"] - e["submit"])
                if e["submit"] is not None and e["admit"] is not None:
                    by["wait"].append(e["admit"] - e["submit"])
                if e["first_token"] is not None and e["tokens"] > 1:
                    by["tpot"].append((e["retire"] - e["first_token"])
                                      / (e["tokens"] - 1))
            elif e["shed_reason"] is not None:
                by["shed"] += 1
                by["slo_missed_shed"] += int(e["deadline"] is not None)
        out: Dict[str, dict] = {}
        agg = {"n": 0, "served": 0, "shed": 0, "tokens": 0, "on_time": 0,
               "slo_missed_served": 0, "slo_missed_shed": 0,
               "ttft": [], "wait": [], "tpot": []}
        for p, by in sorted(classes.items()):
            for k in agg:
                agg[k] = (agg[k] + by[k]) if not isinstance(agg[k], list) \
                    else agg[k] + by[k]
            out[f"class{p}"] = self._finalize(by)
        out["aggregate"] = self._finalize(agg)
        return out

    @staticmethod
    def _finalize(by: dict) -> dict:
        cell = {k: by[k] for k in ("n", "served", "shed", "tokens",
                                   "slo_missed_served", "slo_missed_shed")}
        cell["goodput"] = by["on_time"] / by["n"] if by["n"] else None
        for name in ("ttft", "wait", "tpot"):
            for k, v in _pcts(by[name]).items():
                cell[f"{name}_{k}"] = v
        return cell

    def format_table(self) -> str:
        rows = ["class      n  served  shed  goodput  ttft p50/p90/p99  "
                "wait p50/p90/p99  tpot p50   slo miss (served/shed)"]

        def fm(v, spec="{:.0f}"):
            return "-" if v is None else spec.format(v)

        for name, c in self.report().items():
            rows.append(
                f"{name:<9} {c['n']:>3}  {c['served']:>6}  {c['shed']:>4}  "
                f"{fm(c['goodput'], '{:.0%}'):>7}  "
                f"{fm(c['ttft_p50'])}/{fm(c['ttft_p90'])}/"
                f"{fm(c['ttft_p99']):<10} "
                f"{fm(c['wait_p50'])}/{fm(c['wait_p90'])}/"
                f"{fm(c['wait_p99']):<10} "
                f"{fm(c['tpot_p50'], '{:.2f}'):>8}   "
                f"{c['slo_missed_served']}/{c['slo_missed_shed']}")
        return "\n".join(rows)

    def starved(self) -> List[int]:
        """Requests that neither retired nor were shed — submitted work the
        run abandoned.  Non-empty at drain means starvation."""
        return sorted(rid for rid, e in self._rec.items()
                      if e["retire"] is None and e["shed_reason"] is None)


# ---------------------------------------------------------------------------
# N-replica fleet behind a least-loaded router
# ---------------------------------------------------------------------------

class ReplicaRouter:
    """An in-process N-replica fleet: the single-host step toward the
    ROADMAP k8s fleet.  ``submit`` routes each request to the least-loaded
    replica (outstanding requests, then live tokens, then replica index —
    deterministic, so a routed run is replayable); ``step`` advances every
    replica one engine step in lockstep."""

    def __init__(self, engines: Sequence[ServingEngine]):
        if not engines:
            raise ValueError("router needs at least one replica")
        self.engines = list(engines)

    def _load(self, eng: ServingEngine):
        outstanding = (len(eng.queue) + len(eng._swapped)
                       + sum(r is not None for r in eng.active))
        live_tokens = sum(int(eng.pos[s])
                          for s in range(eng.max_slots)
                          if eng.active[s] is not None)
        return (outstanding, live_tokens)

    def route(self, req: Request) -> ServingEngine:
        return min(enumerate(self.engines),
                   key=lambda ie: self._load(ie[1]) + (ie[0],))[1]

    def submit(self, req: Request) -> str:
        return self.route(req).submit(req)

    def step(self) -> int:
        return sum(eng.step() for eng in self.engines)

    @property
    def step_count(self) -> int:
        return self.engines[0].step_count

    @property
    def drained(self) -> bool:
        return all(eng.drained for eng in self.engines)

    @property
    def recorder(self):
        return self.engines[0].recorder

    @recorder.setter
    def recorder(self, rec) -> None:
        for eng in self.engines:
            eng.recorder = rec

    def stats(self) -> dict:
        """Fleet-wide census: the sum of every replica's SchedulerStats."""
        total: Dict[str, int] = {}
        for eng in self.engines:
            for f in dataclasses.fields(eng.fabric_stats):
                total[f.name] = (total.get(f.name, 0)
                                 + getattr(eng.fabric_stats, f.name))
        return total

    def pending_census(self) -> str:
        return " | ".join(f"replica{i}: {eng.pending_census()}"
                          for i, eng in enumerate(self.engines))


# ---------------------------------------------------------------------------
# trace replay
# ---------------------------------------------------------------------------

def drive(target: Union[ServingEngine, ReplicaRouter],
          trace: Sequence[TraceRecord],
          recorder: Optional[MetricsRecorder] = None,
          max_steps: int = 10_000) -> MetricsRecorder:
    """Replay a trace against one engine or a router fleet: submit each
    record at its arrival step, step until every request retired or was
    shed.  Raises with the pending census when ``max_steps`` runs out with
    work stranded (the starvation signal the tests assert on)."""
    recorder = recorder if recorder is not None else MetricsRecorder()
    target.recorder = recorder
    recorder.requests = {}             # rid → the Request objects submitted
    pend = sorted(trace, key=lambda t: (t.arrival_step, t.rid))
    i = 0
    for _ in range(max_steps):
        step = target.step_count
        while i < len(pend) and pend[i].arrival_step <= step:
            req = pend[i].to_request()
            recorder.requests[req.rid] = req
            recorder.record_submit(req, step)
            target.submit(req)
            i += 1
        if target.step() == 0 and i == len(pend) and target.drained:
            return recorder
    raise RuntimeError(
        f"drive: {max_steps} steps exhausted with "
        f"{len(recorder.starved())} submitted requests stranded "
        f"(rids {recorder.starved()[:8]}...) and {len(pend) - i} not yet "
        f"arrived — {target.pending_census()}")


# ---------------------------------------------------------------------------
# fault soak
# ---------------------------------------------------------------------------

def fault_soak(make_engine, trace: Sequence[TraceRecord], injector,
               max_steps: int = 10_000):
    """Run the same seeded trace twice — fault-free, then under
    ``injector`` — and assert graceful degradation:

    * every request served in both runs committed **bit-identical**
      tokens (faults reschedule, they never corrupt);
    * requests without deadlines reach the same terminal outcome in both
      runs (a fault may delay a *deadlined* request past its SLO — that
      flips served→shed and is exactly what the split census counts);
    * **zero page leaks** at drain: ``PagePool.check()`` clean, no pages
      in use, swap space empty — on both runs.

    ``make_engine(fault_injector=...)`` must build a fresh engine (or
    :class:`ReplicaRouter`) per run.  Returns ``(ref_recorder,
    soak_recorder, soak_target)``.
    """
    # request objects are fresh per run; token streams are compared through
    # the rid → Request map drive() captures at submit time
    def run_and_capture(inj):
        target = make_engine(fault_injector=inj)
        rec = drive(target, trace, max_steps=max_steps)
        engines = (target.engines if isinstance(target, ReplicaRouter)
                   else [target])
        for eng in engines:
            if eng.kv.paged:
                eng.kv.pool.check()
                assert eng.kv.pool.pages_in_use == 0, \
                    f"page leak at drain: {eng.kv.pool.pages_in_use} in use"
            assert eng._swap_pages_used == 0 and not eng._swapped, \
                "swap space not drained"
        return target, rec, rec.requests

    _, ref_rec, ref_reqs = run_and_capture(None)
    soak_target, soak_rec, soak_reqs = run_and_capture(injector)
    for t in trace:
        a, b = ref_reqs[t.rid], soak_reqs[t.rid]
        if a.shed_reason is None and b.shed_reason is None:
            assert a.generated == b.generated, (
                f"request {t.rid}: fault-soak tokens diverged from the "
                f"fault-free run ({a.generated[:6]}... vs "
                f"{b.generated[:6]}...)")
        elif t.deadline is None:
            raise AssertionError(
                f"request {t.rid} (no deadline) shed in one run only: "
                f"ref={a.shed_reason} soak={b.shed_reason} — shedding "
                f"without a deadline must be schedule-independent")
    return ref_rec, soak_rec, soak_target
