from repro.serving.engine import ServingEngine, Request
from repro.serving.traffic import (MetricsRecorder, ReplicaRouter,
                                   TraceRecord, TrafficConfig, drive,
                                   fault_soak, generate_trace, load_trace,
                                   save_trace, trace_t_max)

__all__ = ["ServingEngine", "Request", "TrafficConfig", "TraceRecord",
           "MetricsRecorder", "ReplicaRouter", "generate_trace", "drive",
           "fault_soak", "save_trace", "load_trace", "trace_t_max"]
