from repro.serving.engine import ServingEngine, Request

__all__ = ["ServingEngine", "Request"]
