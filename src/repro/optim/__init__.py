from repro.optim.optimizer import (OptState, init_opt_state, adamw_update,
                                   lr_schedule, global_norm, clip_by_global_norm)

__all__ = ["OptState", "init_opt_state", "adamw_update", "lr_schedule",
           "global_norm", "clip_by_global_norm"]
