"""AdamW with mixed precision, global-norm clipping and LR scheduling.

Built from scratch (no optax in the environment).  The optimizer state holds
fp32 first/second moments and (optionally) an fp32 master copy of bf16
params.  Under ZeRO-1 the launch layer shards every state leaf over the data
axis (each data-parallel rank owns a slice of m/v/master); the update is a
pure element-wise map so GSPMD keeps it fully local, with the reduce-scatter /
all-gather pair induced by the gradient and parameter shardings.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import TrainConfig


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class OptState:
    step: jax.Array
    m: Any
    v: Any
    master: Optional[Any]


def init_opt_state(params, tcfg: TrainConfig, master: bool = True) -> OptState:
    zeros32 = lambda p: jnp.zeros(p.shape, jnp.float32)
    return OptState(
        step=jnp.zeros((), jnp.int32),
        m=jax.tree.map(zeros32, params),
        v=jax.tree.map(zeros32, params),
        master=(jax.tree.map(lambda p: p.astype(jnp.float32), params)
                if master else None),
    )


def lr_schedule(step: jax.Array, tcfg: TrainConfig) -> jax.Array:
    """Linear warmup → cosine decay to 10% of peak."""
    step = step.astype(jnp.float32)
    warm = tcfg.lr * step / max(tcfg.warmup_steps, 1)
    frac = jnp.clip((step - tcfg.warmup_steps)
                    / max(tcfg.total_steps - tcfg.warmup_steps, 1), 0.0, 1.0)
    cos = tcfg.lr * (0.1 + 0.45 * (1.0 + jnp.cos(jnp.pi * frac)))
    return jnp.where(step < tcfg.warmup_steps, warm, cos)


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale), grads), norm


def adamw_update(grads, state: OptState, params, tcfg: TrainConfig):
    """One AdamW step.  Returns (new_params, new_state, metrics)."""
    grads32, gnorm = clip_by_global_norm(grads, tcfg.grad_clip)
    step = state.step + 1
    lr = lr_schedule(step, tcfg)
    b1, b2 = tcfg.beta1, tcfg.beta2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    new_m = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g, state.m, grads32)
    new_v = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * g * g,
                         state.v, grads32)

    ref = state.master if state.master is not None else params

    def upd(p, m, v):
        p32 = p.astype(jnp.float32)
        u = (m / bc1) / (jnp.sqrt(v / bc2) + 1e-8)
        return p32 - lr * (u + tcfg.weight_decay * p32)

    new_ref = jax.tree.map(upd, ref, new_m, new_v)
    if state.master is not None:
        new_master = new_ref
        new_params = jax.tree.map(lambda r, p: r.astype(p.dtype),
                                  new_ref, params)
    else:
        new_master = None
        new_params = jax.tree.map(lambda r, p: r.astype(p.dtype),
                                  new_ref, params)
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_params, OptState(step, new_m, new_v, new_master), metrics
