"""Medusa transposition unit (paper §III-A) — faithful model + TPU-native form.

Two implementations of the same semantics live here:

1. :func:`medusa_transpose_cycle_accurate` — the paper's pipeline, cycle by
   cycle: at cycle ``c`` read the diagonal ``(i, (c+i) mod N)`` from the banked
   input buffer (one word per bank — conflict-free), left-rotate by ``c`` with
   the barrel unit, and store into output bank ``j`` at address ``(j+c) mod N``.
   After exactly N cycles the output banks hold the transpose.  This model is
   used for semantics/latency/interference validation, mirroring Fig. 4.

2. :func:`medusa_transpose` — the TPU-native production form: a binary-exchange
   (Eklundh) network with ``log2(N)`` stages.  Stage ``l`` exchanges bit ``l``
   between the row and column index: one static bit-flip block swap (a
   multi-axis ``reverse`` over the 2-blocks at depth ``l`` — the wires of a
   barrel-shifter layer) and a 2-to-1 select.  Per line of N words this costs
   ``W_line x log2(N)`` one-bit 2-to-1 selects — *exactly* the paper's Medusa
   mux count (§III-D) — versus a gather/crossbar's ``W_line x (N-1)`` (§II-B).
   No gathers, no index tensors: every stage lowers to reshape+reverse+select.
   (An earlier form spelled the block swap as two double-rolls; the reverse
   form is the same exchange with the roll lanes that the select never reads
   removed — bit-identical, ~6x fewer HLO ops on the unrolled path.)

Coordinate convention (matches Fig. 4): the input buffer is a matrix
``I[bank, addr]`` where word ``(x=port, y=index-within-line)`` sits in bank
``y`` at address ``x``; the output buffer is ``O[bank=port, addr=index]``.
Thus ``O = I.T`` over the (bank, addr) physical coordinates — bank index is
the lane (minor) dimension on TPU, address the sublane dimension.
"""

from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.rotation import barrel_rotate, _num_stages


# ----------------------------------------------------------------------------
# 1. Faithful cycle-accurate pipeline (paper Fig. 4)
# ----------------------------------------------------------------------------

def medusa_transpose_cycle_accurate(input_banks: jax.Array,
                                    return_trace: bool = False):
    """Run the N-cycle transposition pipeline on ``input_banks`` [N, N, W].

    ``input_banks[b, a]`` is the word at address ``a`` of bank ``b``; with the
    paper's placement that is word ``(x=a, y=b)``.  Returns output banks
    ``O[b, a]`` = word ``(x=b, y=a)``, i.e. the (bank, addr) transpose, plus —
    optionally — the per-cycle trace of (diagonal, rotated, partial output)
    used by the latency/interference tests.
    """
    n = input_banks.shape[0]
    if input_banks.shape[1] != n:
        raise ValueError("cycle-accurate unit operates on square [N, N, ...] tiles")
    out = jnp.zeros_like(input_banks)
    banks = jnp.arange(n)
    trace = []
    for c in range(n):
        # Diagonal read: bank b supplies address (b - c) mod N → word ((b-c)%N, b).
        diag = input_banks[banks, (banks - c) % n]            # [N, W...]
        # Barrel rotation: left-rotate by c (paper §III-B).
        rot = barrel_rotate(diag, jnp.int32(c), axis=0)
        # Transposed store: bank j writes address (j + c) mod N.
        out = out.at[banks, (banks + c) % n].set(rot)
        if return_trace:
            trace.append((diag, rot, out))
    return (out, trace) if return_trace else out


def transposition_latency_cycles(n_ports: int) -> int:
    """Constant latency overhead of the unit (paper §III-E): N = W_line/W_acc."""
    return n_ports


# ----------------------------------------------------------------------------
# 2. TPU-native log-stage transposition (production path)
# ----------------------------------------------------------------------------

def _bit_flip_both(x: jax.Array, axis0: int, axis1: int, level: int) -> jax.Array:
    """``out[.., i, .., j, ..] = x[.., i^s, .., j^s, ..]`` for ``s = 2**level``:
    flip bit ``level`` of both exchange axes at once.  Splitting each axis as
    ``(n/2s, 2, s)`` makes the flip a reverse of the two 2-sized axes — one
    multi-dim HLO ``reverse`` between two free reshapes (the static wiring of
    one barrel-shifter layer, with the lanes the select never reads removed).
    """
    n, s = x.shape[axis0], 1 << level
    a0, a1 = (axis0, axis1) if axis0 < axis1 else (axis1, axis0)
    shp = (x.shape[:a0] + (n // (2 * s), 2, s)
           + x.shape[a0 + 1:a1] + (n // (2 * s), 2, s) + x.shape[a1 + 1:])
    return jnp.flip(x.reshape(shp), axis=(a0 + 1, a1 + 3)).reshape(x.shape)


@partial(jax.jit, static_argnames=("axis0", "axis1"))
def medusa_transpose(x: jax.Array, axis0: int = 0, axis1: int = 1) -> jax.Array:
    """Transpose the two (equal, power-of-two) axes of ``x`` with a
    binary-exchange network: log2(N) stages of static block swaps + selects.

    Stage ``l`` (block size ``s = 2**l``) swaps bit ``l`` between the two
    indices: an element at ``(i, j)`` with ``bit_l(i) != bit_l(j)`` takes the
    value from ``(i^s, j^s)`` (both bits flip), everything else stays.  The
    partner array is one static bit-flip block swap (:func:`_bit_flip_both`),
    the choice one 2-to-1 select on an iota mask.  Equivalent to
    ``jnp.swapaxes`` but lowers to reverse/select chains (the barrel-shifter
    analogue) instead of a transpose or gather — this is the kernel-level
    trick Medusa brings to the VPU.
    """
    n = x.shape[axis0]
    if x.shape[axis1] != n:
        raise ValueError(
            f"medusa_transpose needs square axes, got {x.shape[axis0]} x {x.shape[axis1]}")
    stages = _num_stages(n)
    for level in range(stages):
        flipped = _bit_flip_both(x, axis0, axis1, level)
        x = jnp.where(_swap_mask(x.ndim, n, axis0, axis1, level), flipped, x)
    return x


def _swap_mask(ndim: int, n: int, axis0: int, axis1: int, level: int):
    """Stage ``level``'s select control — positions where bit ``level`` of
    the two exchange indices differ.  The pattern is static (it is the mux
    wiring of the stage), so it embeds as a compile-time boolean constant
    broadcast over the payload axes rather than runtime iota arithmetic."""
    i = np.arange(n)
    bit = (((i[:, None] ^ i[None, :]) >> level) & 1).astype(bool)
    shape = [1] * ndim
    shape[axis0], shape[axis1] = n, n
    return jnp.asarray(bit.reshape(shape))    # xor-symmetric: order-free


# ----------------------------------------------------------------------------
# 3. Line-stream <-> banked port-stream conversion (the interconnect data path)
# ----------------------------------------------------------------------------
#
# Round-robin arbitration (paper §I obs. 1: even static partition) delivers
# line ``l`` to port ``l % N``.  A group of N consecutive lines forms a square
# tile ``[N(line=addr), N(word=lane)]``; the read network's physical job is to
# re-bank it so each port owns a deep-narrow bank: ``[N(word=addr),
# N(port=lane)]``.  That is one (sublane, lane) transpose per tile — done by
# the log-stage exchange network.  The group axis is a major relabel (free).

def _check_line_stream(lines: jax.Array, n_ports: int) -> None:
    if lines.ndim < 2:
        raise ValueError("line stream must be [num_lines, n_words, ...]")
    if lines.shape[0] % n_ports != 0:
        raise ValueError(
            f"num_lines={lines.shape[0]} must be a multiple of n_ports={n_ports}")
    if lines.shape[1] != n_ports:
        raise ValueError(
            f"each line carries W_line = N x W_acc: expected {n_ports} words, "
            f"got {lines.shape[1]}")


@partial(jax.jit, static_argnames=("n_ports",))
def read_network_medusa(lines: jax.Array, n_ports: int) -> jax.Array:
    """Read network: line stream ``[L, N, W]`` → banked ``[G, N, N, W]`` where
    ``banked[g, y, p] = lines[g*N + p, y]`` (addr=word-index, lane=port)."""
    n = n_ports
    _check_line_stream(lines, n)
    groups = lines.shape[0] // n
    tiles = lines.reshape((groups, n, n) + lines.shape[2:])
    return medusa_transpose(tiles, axis0=1, axis1=2)


@partial(jax.jit, static_argnames=("n_ports",))
def write_network_medusa(banked: jax.Array, n_ports: int) -> jax.Array:
    """Write network (paper §III-A-2): banked ``[G, N, N, W]`` → lines
    ``[G*N, N, W]`` — the inverse transposition, data flowing to DRAM."""
    n = n_ports
    if banked.shape[1] != n or banked.shape[2] != n:
        raise ValueError(f"expected [G, N, N, ...] banked buffer, got {banked.shape}")
    tiles = medusa_transpose(banked, axis0=1, axis1=2)
    return tiles.reshape((tiles.shape[0] * n, n) + tiles.shape[3:])


def read_network_oracle(lines: jax.Array, n_ports: int) -> jax.Array:
    """Pure-jnp oracle for the read network (reshape + swapaxes)."""
    n = n_ports
    _check_line_stream(lines, n)
    groups = lines.shape[0] // n
    tiles = lines.reshape((groups, n, n) + lines.shape[2:])
    return jnp.swapaxes(tiles, 1, 2)


def write_network_oracle(banked: jax.Array, n_ports: int) -> jax.Array:
    n = n_ports
    tiles = jnp.swapaxes(banked, 1, 2)
    return tiles.reshape((tiles.shape[0] * n, n) + tiles.shape[3:])


def port_stream(banked: jax.Array, port: int) -> jax.Array:
    """Consumer view: port ``p`` reads its own deep-narrow bank (lane column)."""
    return banked[..., port, :] if banked.ndim >= 4 else banked[..., port]


def port_major_view(banked: jax.Array) -> jax.Array:
    """Logical ``[N_port, G, N_word, W]`` view of the banked buffer (for
    consumers that want a per-port leading axis; a relabel of the same data)."""
    return jnp.moveaxis(banked, 2, 0)


def transpose_oracle(x: jax.Array, axis0: int = 0, axis1: int = 1) -> jax.Array:
    return jnp.swapaxes(x, axis0, axis1)


# ----------------------------------------------------------------------------
# 4. Rectangular layout conversion built from square tiles
# ----------------------------------------------------------------------------

def _pow2_at_most(n: int) -> int:
    return 1 << int(math.floor(math.log2(n)))


@partial(jax.jit, static_argnames=("tile",))
def medusa_swap_minor(x: jax.Array, tile: int = 0) -> jax.Array:
    """Transpose the last two axes of ``x`` (any rectangular shape) using the
    log-stage network on square power-of-two tiles.

    Rows/cols are padded up to a multiple of the tile; the tile grid transpose
    is a major-dim relabel, each tile transposes through the exchange network.
    This is the building block behind the KV-cache layout engine
    ([T, H, D] ↔ [H, T, D]) and the reference semantics for the Pallas kernel.
    """
    r, c = x.shape[-2], x.shape[-1]
    if tile == 0:
        tile = min(_pow2_at_most(max(r, 1)), _pow2_at_most(max(c, 1)), 128)
        tile = max(tile, 1)
    pr = (-r) % tile
    pc = (-c) % tile
    if pr or pc:
        pad = [(0, 0)] * (x.ndim - 2) + [(0, pr), (0, pc)]
        x = jnp.pad(x, pad)
    rr, cc = x.shape[-2], x.shape[-1]
    lead = x.shape[:-2]
    g = x.reshape(lead + (rr // tile, tile, cc // tile, tile))
    g = jnp.swapaxes(g, -3, -2)                 # [.., R, C, tile, tile] grid-major
    g = medusa_transpose(g, axis0=g.ndim - 2, axis1=g.ndim - 1)
    g = jnp.swapaxes(g, -4, -3)                 # transpose the (major) tile grid
    g = jnp.swapaxes(g, -3, -2)
    out = g.reshape(lead + (cc, rr))
    return out[..., :c, :r]
