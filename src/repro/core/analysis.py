"""Logic-complexity and resource models reproducing the paper's accounting.

These are the *analytic* reproductions of the paper's §II-B, §III-D and §IV
numbers — mux counts, BRAM counts, and the resource table ratios — used by the
benchmark suite to validate our implementation against the paper's own claims
before any TPU-side measurement.
"""

from __future__ import annotations

import dataclasses
import math

from repro.core.rotation import (baseline_mux_count, medusa_mux_count,
                                 mux_reduction, rotation_depth)
from repro.core.baseline import fifo_bram_cost, medusa_bank_bram_cost


@dataclasses.dataclass(frozen=True)
class InterconnectConfig:
    """One design point of the interconnect (paper §IV-C uses 512/16/32/32)."""

    w_line: int = 512             # DRAM controller interface width, bits
    w_acc: int = 16               # accelerator port width, bits
    n_read_ports: int = 32
    n_write_ports: int = 32
    max_burst: int = 32           # lines per burst buffered per port

    @property
    def n(self) -> int:
        n = self.w_line // self.w_acc
        assert n == self.n_read_ports, "ports must evenly split the line"
        return n

    @property
    def latency_cycles(self) -> int:
        """Constant latency overhead (§III-E): W_line / W_acc cycles."""
        return self.w_line // self.w_acc


@dataclasses.dataclass(frozen=True)
class ResourceEstimate:
    mux_bits_read: int
    mux_bits_write: int
    bram_read: int
    bram_write: int
    logic_depth: int

    @property
    def mux_bits_total(self) -> int:
        return self.mux_bits_read + self.mux_bits_write


def baseline_resources(cfg: InterconnectConfig) -> ResourceEstimate:
    """Baseline (§II): W_line x (N-1) muxes/direction; FIFOs in LUTRAM (0
    BRAM, as in Table II) — or ``fifo_bram_cost`` x N each if BRAM-mapped."""
    return ResourceEstimate(
        mux_bits_read=baseline_mux_count(cfg.w_line, cfg.n_read_ports),
        mux_bits_write=baseline_mux_count(cfg.w_line, cfg.n_write_ports),
        bram_read=0,
        bram_write=0,
        logic_depth=int(math.ceil(math.log2(max(cfg.n_read_ports, 2)))),
    )


def baseline_bram_mapped(cfg: InterconnectConfig) -> int:
    """If the baseline's wide shallow FIFOs were BRAM-mapped: 15 BRAMs per
    32x512b FIFO → 960 for 64 ports (§IV-C) — the poor trade-off the paper
    calls out."""
    per_fifo = fifo_bram_cost(cfg.max_burst, cfg.w_line)
    return per_fifo * (cfg.n_read_ports + cfg.n_write_ports)


def medusa_resources(cfg: InterconnectConfig) -> ResourceEstimate:
    """Medusa (§III-D): W_line x log2(N) rotation muxes/direction; deep-narrow
    banks map to 1 BRAM each (32/direction at the paper's design point)."""
    return ResourceEstimate(
        mux_bits_read=medusa_mux_count(cfg.w_line, cfg.n_read_ports),
        mux_bits_write=medusa_mux_count(cfg.w_line, cfg.n_write_ports),
        bram_read=medusa_bank_bram_cost(cfg.n_read_ports, cfg.w_acc, cfg.max_burst),
        bram_write=medusa_bank_bram_cost(cfg.n_write_ports, cfg.w_acc, cfg.max_burst),
        logic_depth=rotation_depth(cfg.n_read_ports),
    )


def paper_design_point() -> InterconnectConfig:
    """The §IV-C design point: 512-bit DDR3 interface, 32r+32w 16-bit ports."""
    return InterconnectConfig()


def complexity_summary(cfg: InterconnectConfig) -> dict:
    base = baseline_resources(cfg)
    med = medusa_resources(cfg)
    return {
        "w_line": cfg.w_line,
        "n_ports": cfg.n_read_ports,
        "baseline_mux_bits": base.mux_bits_total,
        "medusa_mux_bits": med.mux_bits_total,
        "mux_reduction": mux_reduction(cfg.w_line, cfg.n_read_ports),
        "baseline_bram_if_mapped": baseline_bram_mapped(cfg),
        "medusa_bram": med.bram_read + med.bram_write,
        "latency_overhead_cycles": cfg.latency_cycles,
        "baseline_logic_depth": base.logic_depth,
        "medusa_logic_depth": med.logic_depth,
    }


# Paper-reported figures used as validation targets by the benchmarks.
PAPER_TABLE2 = {
    "baseline": {"read_lut": 18168, "read_ff": 19210, "write_lut": 26810,
                 "write_ff": 35451, "read_bram": 0, "write_bram": 0},
    "medusa": {"read_lut": 4733, "read_ff": 4759, "write_lut": 4777,
               "write_ff": 4325, "read_bram": 32, "write_bram": 32},
    "claimed_lut_reduction": 4.73,
    "claimed_ff_reduction": 6.02,
    "claimed_freq_gain": 1.8,
}


def paper_reported_reductions() -> tuple[float, float]:
    t = PAPER_TABLE2
    lut = ((t["baseline"]["read_lut"] + t["baseline"]["write_lut"])
           / (t["medusa"]["read_lut"] + t["medusa"]["write_lut"]))
    ff = ((t["baseline"]["read_ff"] + t["baseline"]["write_ff"])
          / (t["medusa"]["read_ff"] + t["medusa"]["write_ff"]))
    return lut, ff
