"""High-level interconnect API — the framework-facing entry point.

``Interconnect`` bundles the read/write data-transfer networks behind an
implementation switch so every consumer in the framework (KV-cache layout
engine, MoE dispatch, weight streaming) can select:

* ``"medusa"``   — the paper's transposition network (log-stage rolls+selects;
  Pallas kernel on TPU via :mod:`repro.kernels.ops` when tile shapes allow),
* ``"crossbar"`` — the traditional gather-based baseline (paper §II),
* ``"oracle"``   — plain reshape/swapaxes (semantics reference).

All three are value-identical; they differ only in the HLO they emit, which is
exactly what the paper's resource/frequency comparison becomes on TPU.
"""

from __future__ import annotations

import dataclasses
from typing import Literal

import jax

from repro.core import transpose as _t
from repro.core import baseline as _b

Impl = Literal["medusa", "crossbar", "oracle"]


@dataclasses.dataclass(frozen=True)
class Interconnect:
    """A W_line ↔ N x W_acc data-transfer network with selectable fabric."""

    n_ports: int
    impl: Impl = "medusa"

    def read(self, lines: jax.Array) -> jax.Array:
        """Read network: DRAM line stream ``[L, N, W]`` → banked port buffer
        ``[G, N(word-addr), N(port-lane), W]``."""
        if self.impl == "medusa":
            return _t.read_network_medusa(lines, self.n_ports)
        if self.impl == "crossbar":
            return _b.read_network_crossbar(lines, self.n_ports)
        return _t.read_network_oracle(lines, self.n_ports)

    def write(self, banked: jax.Array) -> jax.Array:
        """Write network: banked port buffer → DRAM line stream."""
        if self.impl == "medusa":
            return _t.write_network_medusa(banked, self.n_ports)
        if self.impl == "crossbar":
            return _b.write_network_crossbar(banked, self.n_ports)
        return _t.write_network_oracle(banked, self.n_ports)

    def swap_minor(self, x: jax.Array) -> jax.Array:
        """Layout engine: transpose the two minor axes of ``x`` (rectangular
        OK) — e.g. KV cache [T, H*D-line] ↔ [H, T-stream].  Uses the fabric
        selected by ``impl``."""
        if self.impl == "medusa":
            return _t.medusa_swap_minor(x)
        if self.impl == "crossbar":
            # gather-based transpose: explicit index routing (over-provisioned)
            import jax.numpy as jnp
            r, c = x.shape[-2], x.shape[-1]
            i = jax.lax.broadcasted_iota(jnp.int32, x.shape[:-2] + (c, r), x.ndim - 2)
            j = jax.lax.broadcasted_iota(jnp.int32, x.shape[:-2] + (c, r), x.ndim - 1)
            flat = x.reshape(x.shape[:-2] + (r * c,))
            return jnp.take_along_axis(flat, (j * c + i).reshape(x.shape[:-2] + (c * r,)),
                                       axis=-1).reshape(x.shape[:-2] + (c, r))
        return _t.transpose_oracle(x, x.ndim - 2, x.ndim - 1)

    @property
    def latency_cycles(self) -> int:
        return _t.transposition_latency_cycles(self.n_ports)
