"""DEPRECATED: use :mod:`repro.fabric` instead.

``Interconnect`` was the original framework-facing entry point to the
read/write data-transfer networks.  The fabric subsystem
(:class:`repro.fabric.Fabric`) absorbed it — plus the burst scheduler and the
paged KV layout — so every consumer shares one memory-movement API.  This
shim keeps the old constructor working; each method delegates to a
:class:`~repro.fabric.Fabric` built from the same (n_ports, impl) pair.
"""

from __future__ import annotations

import dataclasses
import warnings
from typing import Literal

import jax

Impl = Literal["medusa", "crossbar", "oracle"]


def _fabric(n_ports: int, impl: str):
    # local import: repro.fabric imports repro.core submodules, so importing
    # it at module scope would cycle through this package's __init__.
    from repro.fabric import Fabric
    return Fabric.make(n_ports=n_ports, impl=impl)


@dataclasses.dataclass(frozen=True)
class Interconnect:
    """Deprecated alias for :class:`repro.fabric.Fabric` (same semantics)."""

    n_ports: int
    impl: Impl = "medusa"

    def __post_init__(self):
        warnings.warn(
            "repro.core.interconnect.Interconnect is deprecated; use "
            "repro.fabric.Fabric (Fabric.make(n_ports, impl) or "
            "Fabric.for_model(cfg))", DeprecationWarning, stacklevel=2)

    def read(self, lines: jax.Array) -> jax.Array:
        return _fabric(self.n_ports, self.impl).read(lines)

    def write(self, banked: jax.Array) -> jax.Array:
        return _fabric(self.n_ports, self.impl).write(banked)

    def swap_minor(self, x: jax.Array) -> jax.Array:
        return _fabric(self.n_ports, self.impl).swap_minor(x)

    @property
    def latency_cycles(self) -> int:
        return _fabric(self.n_ports, self.impl).latency_cycles
