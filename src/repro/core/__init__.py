"""Medusa core: transposition-based memory interconnect (the paper's contribution)."""

from repro.core.rotation import (barrel_rotate, index_twist, baseline_mux_count,
                                 medusa_mux_count, mux_reduction, rotation_depth)
from repro.core.transpose import (medusa_transpose, medusa_transpose_cycle_accurate,
                                  medusa_swap_minor, read_network_medusa,
                                  write_network_medusa, read_network_oracle,
                                  write_network_oracle, port_stream,
                                  port_major_view, transposition_latency_cycles)
from repro.core.baseline import (read_network_crossbar, write_network_crossbar,
                                 width_convert_onehot)
from repro.core.interconnect import Interconnect
from repro.core.analysis import (InterconnectConfig, baseline_resources,
                                 medusa_resources, complexity_summary,
                                 paper_design_point, PAPER_TABLE2,
                                 paper_reported_reductions)

__all__ = [
    "barrel_rotate", "index_twist", "baseline_mux_count", "medusa_mux_count",
    "mux_reduction", "rotation_depth", "medusa_transpose",
    "medusa_transpose_cycle_accurate", "medusa_swap_minor",
    "read_network_medusa", "write_network_medusa", "read_network_oracle",
    "write_network_oracle", "port_stream", "port_major_view",
    "transposition_latency_cycles", "read_network_crossbar",
    "write_network_crossbar", "width_convert_onehot", "Interconnect",
    "InterconnectConfig", "baseline_resources", "medusa_resources",
    "complexity_summary", "paper_design_point", "PAPER_TABLE2",
    "paper_reported_reductions",
]
