"""Baseline (traditional) memory interconnect — paper §II.

The baseline read network is a 1-to-N demux feeding N wide shallow FIFOs, each
followed by an N-to-1 width converter; the write network is the mirror image.
Its cost is ``W_line x (N-1)`` one-bit 2-to-1 muxes per direction
(O(Bandwidth x NumPorts), §II-B) and its wide distributed buses are what kill
FPGA routing at scale (§II-C).

On TPU the analogous over-provisioned structure is *content-flexible routing*:
gather / one-hot-matmul selection, which materialises index tensors and
gather/scatter HLO where Medusa emits static roll/select chains.  We implement
the baseline both ways:

* :func:`read_network_crossbar` / :func:`write_network_crossbar` — gather-based
  demux + per-port width-converter (``jnp.take`` with an explicit routing
  index), value-identical to the Medusa network.
* :func:`width_convert_onehot` — the N-to-1 mux modelled as a one-hot matmul
  (each output word selects among N candidates), used by the resource
  benchmarks to census the mux cost in lowered HLO.

Both carry the same request-arbitration semantics as Medusa (§IV: "both
interconnects use the same request arbitration logic").
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp


@partial(jax.jit, static_argnames=("n_ports",))
def read_network_crossbar(lines: jax.Array, n_ports: int) -> jax.Array:
    """Crossbar read network: for every (group, word-addr, port) output slot,
    gather the source word through an explicit routing index — the wide demux
    plus per-port N-to-1 width-converter mux of Fig. 1.

    Output layout matches :func:`repro.core.transpose.read_network_medusa`:
    ``banked[g, y, p] = lines[g*N + p, y]``.
    """
    n = n_ports
    if lines.shape[0] % n or lines.shape[1] != n:
        raise ValueError(f"bad line stream {lines.shape} for N={n}")
    groups = lines.shape[0] // n
    g = jnp.arange(groups)[:, None, None]
    y = jnp.arange(n)[None, :, None]
    p = jnp.arange(n)[None, None, :]
    flat = lines.reshape((groups * n * n,) + lines.shape[2:])
    # Demux: any of the N*N words of a group may be routed to any output slot
    # on any cycle — the full-connectivity crossbar (over-provisioned).
    src = (g * n + p) * n + y
    return jnp.take(flat, src.reshape(-1), axis=0).reshape(
        (groups, n, n) + lines.shape[2:])


@partial(jax.jit, static_argnames=("n_ports",))
def write_network_crossbar(banked: jax.Array, n_ports: int) -> jax.Array:
    """Crossbar write network (Fig. 2): per-port width converters feed wide
    FIFOs, an N-to-1 mux drains them to the memory controller."""
    n = n_ports
    groups = banked.shape[0]
    l = jnp.arange(groups * n)[:, None]
    y = jnp.arange(n)[None, :]
    flat = banked.reshape((groups * n * n,) + banked.shape[3:])
    # banked[g, y, p] sits at flat[(g*n + y)*n + p]; line l = (g, p=l%n).
    src = ((l // n) * n + y) * n + (l % n)
    return jnp.take(flat, src.reshape(-1), axis=0).reshape(
        (groups * n, n) + banked.shape[3:])


@partial(jax.jit, static_argnames=())
def width_convert_onehot(fifo_line: jax.Array, select: jax.Array) -> jax.Array:
    """One step of the baseline data-width converter: an N-to-1 word mux.

    ``fifo_line`` is ``[N, W]`` (one wide FIFO entry), ``select`` the word
    index to present on the narrow port this cycle.  Modelled as a one-hot
    reduction — N-1 two-to-one muxes of width W, the §II-B cost unit.
    """
    n = fifo_line.shape[0]
    onehot = (jnp.arange(n) == select).astype(fifo_line.dtype)
    return jnp.tensordot(onehot, fifo_line, axes=(0, 0))


def fifo_bram_cost(depth_lines: int, w_line: int, bram_bits: int = 18 * 1024,
                   bram_width: int = 36) -> int:
    """BRAM-18K count for one wide shallow FIFO (paper §IV-C accounting).

    A Virtex-7 18-Kbit BRAM is at most 36 bits wide; a ``depth x W_line`` FIFO
    needs ``ceil(W_line / 36)`` BRAMs regardless of (shallow) depth — e.g. a
    32 x 512b FIFO consumes 15 BRAMs, so 64 FIFOs would need 960 (§IV-C).
    """
    del depth_lines, bram_bits  # depth 32 << 512 never adds BRAMs at 36b width
    return -(-w_line // bram_width)


def medusa_bank_bram_cost(n_ports: int, w_acc: int, max_burst: int,
                          bram_bits: int = 18 * 1024) -> int:
    """BRAM-18K count for Medusa's deep-narrow banks: N banks of
    ``(MaxBurstLen x N) x W_acc`` bits each (input+output double buffer is
    counted by the caller).  32 banks of 1024 x 16b = 16 Kbit fit one BRAM
    each → 32 per direction, 64 total (§IV-C)."""
    bank_bits = max_burst * n_ports * w_acc
    per_bank = -(-bank_bits // bram_bits)
    return n_ports * per_bank
