"""Barrel rotation unit (paper §III-B) and index-twist networks.

The paper's rotation unit takes N words of W_acc bits and left-rotates them by
``c mod N`` positions using a barrel-shifter: ``log2(N)`` stages, where stage
``l`` conditionally rotates by ``2**l`` words under bit ``l`` of the rotation
amount.  On TPU the analogous primitive is a full-lane roll (`jnp.roll` /
``pltpu.roll``) composed in the same log-depth structure: each stage is one
full-width vector move plus a 2-to-1 select — no gathers, no index tensors.

This module provides:

* :func:`barrel_rotate` — the faithful log-stage rotation unit (vectorised over
  leading dims), equivalent to ``jnp.roll(x, -amount, axis)`` for left rotation.
* :func:`index_twist` — a row-index-dependent rotation (row ``b`` rotated by
  ``b * direction``) built from the same barrel structure; this is the
  "address generator" of the banked buffers, which on FPGA is free addressing
  and on TPU becomes log2(N) masked rolls.
* mux-count cost models matching the paper's §II-B / §III-D formulas.
"""

from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp


def _num_stages(n: int) -> int:
    if n <= 0 or (n & (n - 1)) != 0:
        raise ValueError(f"barrel rotation requires power-of-two size, got {n}")
    return int(math.log2(n))


@partial(jax.jit, static_argnames=("axis",))
def barrel_rotate(x: jax.Array, amount: jax.Array, axis: int = 0) -> jax.Array:
    """Left-rotate ``x`` along ``axis`` by ``amount`` using log2(N) barrel stages.

    Semantically equal to ``jnp.roll(x, -amount, axis=axis)`` but built from the
    paper's structure: stage ``l`` rotates by ``2**l`` iff bit ``l`` of
    ``amount mod N`` is set.  Each stage lowers to a static roll (slice+concat)
    and a select — the TPU analogue of one mux layer.
    """
    n = x.shape[axis]
    stages = _num_stages(n)
    amount = jnp.asarray(amount, dtype=jnp.int32) % n
    for level in range(stages):
        bit = (amount >> level) & 1
        rotated = jnp.roll(x, -(1 << level), axis=axis)
        x = jnp.where(_expand(bit, x.ndim), rotated, x)
    return x


def _expand(scalar: jax.Array, ndim: int) -> jax.Array:
    return jnp.reshape(scalar.astype(bool), (1,) * ndim)


@partial(jax.jit, static_argnames=("axis", "roll_axis", "direction"))
def index_twist(x: jax.Array, axis: int = 0, roll_axis: int = 1,
                direction: int = -1) -> jax.Array:
    """Rotate slice ``b`` (taken along ``axis``) by ``direction * b`` along
    ``roll_axis``.

    ``direction=-1`` is a left twist: ``out[b, k] = x[b, (k + b) % N]`` (for a
    2-D input with ``axis=0, roll_axis=1``).  ``direction=+1`` is the inverse
    right twist.  Implemented as log2(N) stages of (static roll, masked
    select) — the bank "address generators" of the paper mapped onto the VPU.
    """
    n = x.shape[axis]
    stages = _num_stages(n)
    idx = jax.lax.broadcasted_iota(jnp.int32, x.shape, axis)
    for level in range(stages):
        take_rot = ((idx >> level) & 1).astype(bool)
        rotated = jnp.roll(x, direction * (1 << level), axis=roll_axis)
        x = jnp.where(take_rot, rotated, x)
    return x


# ----------------------------------------------------------------------------
# Logic-complexity cost models (paper §II-B and §III-D)
# ----------------------------------------------------------------------------

def baseline_mux_count(w_line: int, num_ports: int) -> int:
    """2-to-1 one-bit mux count of the baseline data-transfer network.

    Paper §II-B: each of the N width converters performs an N-to-1 mux of
    width ``W_acc = W_line / N`` → ``W_acc × (N-1)`` muxes each, so the total
    is ``W_line × (N-1)``: O(Bandwidth × NumPorts).
    """
    return w_line * (num_ports - 1)


def medusa_mux_count(w_line: int, num_ports: int) -> int:
    """2-to-1 one-bit mux count of the Medusa rotation unit.

    Paper §III-D: log2(N) layers, each layer N muxes of width W_acc =
    ``W_line`` one-bit muxes per layer → ``W_line × log2(N)`` total.
    """
    return w_line * _num_stages(num_ports)


def mux_reduction(w_line: int, num_ports: int) -> float:
    """Baseline/Medusa mux ratio — the paper's headline complexity win."""
    return baseline_mux_count(w_line, num_ports) / medusa_mux_count(w_line, num_ports)


def rotation_depth(num_ports: int) -> int:
    """Logic depth (levels of 2-to-1 muxes) through the rotation unit.

    The FPGA critical path through the rotation unit is log2(N) mux levels;
    the baseline's N-to-1 mux is also log-depth in a balanced tree but its
    *wiring* is O(N) fan-in per port.  We use depth as the frequency-analogue
    term in the scalability benchmark.
    """
    return _num_stages(num_ports)
