"""Burst transfer support (paper §III-C): head/tail pointers, shared deep
buffer, interference-free per-port progress.

This is a cycle-level functional simulator of the Medusa read path under
bursty arrivals.  It exists to *validate the paper's claims*, not to run in
the production data path:

* the input buffer holds ``MaxBurstLen x N`` lines (N banks, deep & narrow);
* per-port head/tail pointers track occupancy; only lines at the head
  pointers participate in rotation;
* a port joins the transposition at the current global phase without waiting
  for other ports (§III-F: no inter-port interference);
* the latency from a line's arrival to its availability at the port is the
  constant ``N`` cycles of §III-E (plus its queueing delay behind earlier
  lines of the same port — a FIFO property shared with the baseline).

The simulator is written with plain numpy-style python control flow over jax
arrays (it is a test vehicle; tests drive it for N <= 16).
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.rotation import barrel_rotate


@dataclasses.dataclass
class MedusaReadSim:
    """State of the read-side transposition unit with burst buffering."""

    n_ports: int
    depth: int                       # lines buffered per port (>= MaxBurstLen)
    word_width: int = 1

    def __post_init__(self):
        n, d, w = self.n_ports, self.depth, self.word_width
        # input banks: [bank=word-idx y, port-region x, slot, W]
        self.in_buf = jnp.zeros((n, n, d, w))
        self.in_valid = jnp.zeros((n, d), dtype=bool)        # [port, slot]
        self.head = jnp.zeros((n,), dtype=jnp.int32)
        self.tail = jnp.zeros((n,), dtype=jnp.int32)
        # progress of the in-flight transposition of each port's head line:
        # number of words already moved (0..N); starts mid-phase when joining.
        self.words_done = jnp.zeros((self.n_ports,), dtype=jnp.int32)
        # output banks: [port, slot, word-idx, W] + completion events
        self.out_buf = jnp.zeros((n, d, n, w))
        self.out_time = -jnp.ones((n, d), dtype=jnp.int32)   # cycle completed
        self.cycle = 0
        self.arrival_time = -jnp.ones((n, d), dtype=jnp.int32)

    # -- DRAM side -----------------------------------------------------------
    def push_line(self, port: int, line: jax.Array) -> None:
        """A full W_line line for ``port`` arrives from the memory controller
        (one line per cycle max — call at most once per :meth:`step`)."""
        n, d = self.n_ports, self.depth
        line = jnp.asarray(line).reshape(n, self.word_width)
        slot = int(self.tail[port]) % d
        if bool(self.in_valid[port, slot]):
            raise RuntimeError(f"port {port} buffer overflow (backpressure)")
        # word y of the line goes to bank y, into this port's region.
        self.in_buf = self.in_buf.at[:, port, slot].set(line)
        self.in_valid = self.in_valid.at[port, slot].set(True)
        self.tail = self.tail.at[port].add(1)
        self.arrival_time = self.arrival_time.at[port, slot].set(self.cycle)

    # -- one clock cycle ------------------------------------------------------
    def step(self) -> None:
        """Advance one cycle of the pipeline (paper Fig. 4 + §III-C/F).

        Bank ``b`` serves the port ``p(b) = (b - c) mod N`` — each active port
        contributes exactly one word per cycle (its phase word
        ``y = (c + p) mod N``), one word per bank, conflict-free.  The barrel
        rotator left-rotates the bank-ordered diagonal by ``c``; output bank
        ``j`` then stores at address ``(j + c) mod N``.  Ports with no valid
        head line simply leave their diagonal slot idle (§III-F: a port joins
        at the current phase without disturbing the others).
        """
        n, d = self.n_ports, self.depth
        c = self.cycle
        ports = jnp.arange(n)
        # Diagonal read, bank-indexed: bank b reads its region for port (b-c)%N.
        p_of_b = (ports - c) % n
        slot_b = self.head[p_of_b] % d
        active_b = self.in_valid[p_of_b, slot_b]
        diag = self.in_buf[ports, p_of_b, slot_b]               # [n, W]
        # Rotation unit (the only data movement): rot[j] = word(x=j, y=(j+c)%N).
        rot = barrel_rotate(jnp.where(active_b[:, None], diag, 0.0),
                            jnp.int32(c % n), axis=0)
        active = barrel_rotate(active_b, jnp.int32(c % n), axis=0)
        # Transposed store: output bank j, address (j + c) mod N, head slot.
        addr = (ports + c) % n
        dest_slot = self.head % d
        cur = self.out_buf[ports, dest_slot, addr]
        self.out_buf = self.out_buf.at[ports, dest_slot, addr].set(
            jnp.where(active[:, None], rot, cur))
        self.words_done = self.words_done + active.astype(jnp.int32)
        finished = self.words_done >= n
        self.out_time = jnp.where(
            (finished & active)[:, None]
            & (jnp.arange(d)[None, :] == dest_slot[:, None]),
            c, self.out_time)
        # Retire finished head lines; pointers advance per port independently.
        self.in_valid = self.in_valid.at[ports, dest_slot].set(
            jnp.where(finished, False, self.in_valid[ports, dest_slot]))
        self.head = jnp.where(finished, self.head + 1, self.head)
        self.words_done = jnp.where(finished, 0, self.words_done)
        self.cycle += 1

    def run(self, cycles: int) -> None:
        for _ in range(cycles):
            self.step()

    # -- accelerator side ------------------------------------------------------
    def pop_line(self, port: int, slot: int) -> jax.Array:
        """Port-side read of a completed line (deep-narrow output bank)."""
        return self.out_buf[port, slot % self.depth]

    def completion_latency(self, port: int, slot: int) -> int:
        """Cycles from arrival to full availability (paper §III-E: <= ~N)."""
        return int(self.out_time[port, slot] - self.arrival_time[port, slot]) + 1
