"""Device-mesh lowering of the fabric: the sharded physical page pool.

The paper's core observation — many narrow accelerator ports funneling into
one wide DRAM bus — reappears one level up at multi-device scale: many
per-slot decode streams funneling into one shared KV pool.  This module
shards that pool over a ``pool`` mesh axis and lowers the sparse-extent
bursts (``Fabric.read_burst(..., indices=)`` / ``write_burst(..., indices=,
into=)``) inside ``shard_map`` as a **two-hop collective**:

1. *local hop* — each shard runs the fused page-table gather on the frames
   it owns (the PR-5 scalar-prefetched burst kernel, per shard, on its
   ``frames/S`` block of the pool's line stream);
2. *exchange hop* — ONE ``lax.all_to_all`` (or ``ring_all_to_all`` — N-1
   ``ppermute`` rotations, selectable via :attr:`FabricConfig.collective`)
   delivers every gathered frame to the shard that requested it.

The exchange network's butterfly stages and the collective's rotation steps
are the same algebra — both are static permutations of whole machine words —
so the lowering is bit-identical to the single-device sparse burst by
construction: the local gathers produce exactly ``take(pool, indices)``
restricted to each shard's rows, the collective is a pure permutation of
those lines, and the requesting shard's placement scatter restores the
request order before the banked reshape.

Ownership is **contiguous-block by physical page**: shard ``s`` owns pages
``[s * P/S, (s+1) * P/S)`` — exactly what ``PartitionSpec("pool")`` on the
leaf's page axis means to jax (:func:`pool_partition_spec`), so the sharded
arrays and the plan agree without any relayout.  Traffic *balance* comes
from the allocator instead: :class:`repro.fabric.PagePool` stripes page
allocation round-robin across the shard blocks (``n_shards``), so a decode
step's live frames spread evenly over shards.

The host side of the split lives in :func:`shard_plan`: given a step's live
frame list it buckets every requested frame by (requesting shard, owning
shard), pads each bucket to a shared ``cap`` with sentinels, and emits the
``fetch``/``place`` index operands both burst directions reuse (reads
deliver pool→ports, writes ports→pool, through the same buckets).  The
off-diagonal buckets are the words that physically cross shards —
``SchedulerStats.words_cross_shard``; with round-robin striping they are
``(S-1)/S`` of the live traffic, always less than ``words_moved``.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.fabric.scheduler import FRAME_SENTINEL as _SENTINEL

POOL_AXIS = "pool"


@dataclasses.dataclass(frozen=True)
class ShardPlan:
    """Host-side plan of one step's cross-shard traffic (one per distinct
    leaf rep count; both burst directions reuse it).

    ``fetch [S(owner), S(requestor), cap]`` — for each owning shard, the
    *local* line-stream rows it sends each requestor (sentinel = padding:
    reads gather zero frames, writes drop).  ``place [S(requestor),
    S(owner), cap]`` — for each requesting shard, the *local* output row of
    each received line (sentinel drops).  ``cap`` is the padded bucket
    size, a multiple of N so every shard's local gather keeps the burst
    index contract.  ``cross_frames``/``local_frames`` count the live
    (non-padding) requests that cross shards vs stay local — the host-side
    census behind the bench's locality split."""

    fetch: np.ndarray
    place: np.ndarray
    k_tot: int
    cap: int
    cross_frames: int
    local_frames: int

    @property
    def n_shards(self) -> int:
        return self.fetch.shape[0]

    def operands(self):
        """The plan's device operands ``(fetch, place)`` (int32)."""
        return jnp.asarray(self.fetch), jnp.asarray(self.place)


def shard_plan(live_idx, frames: int, n_shards: int, n_ports: int,
               reps: int = 1, cap_bucket: int = 0) -> ShardPlan:
    """Split a sparse burst's frame-index list by owning shard (host-side).

    ``live_idx [K]`` are per-pool physical frame indices (entries
    ``>= frames`` are sentinels requesting nothing), ``frames`` the per-rep
    pool frame count, ``reps`` the leaf's leading layer-stack factor (the
    request list is rep-major, matching
    :func:`repro.models.common.pool_rep_indices`).  Output row ``j`` of the
    ``k_tot = reps*K`` line stream is assigned to requesting shard
    ``j // (k_tot/S)`` — the contiguous block ``PartitionSpec("pool")``
    gives it.  ``cap_bucket`` rounds the bucket capacity up (beyond the
    mandatory multiple of N) to bound retrace churn, mirroring the engine's
    live-plan bucketing."""
    idx = np.asarray(live_idx, np.int64)
    s = int(n_shards)
    if s < 1:
        raise ValueError(f"n_shards must be >= 1, got {n_shards}")
    if frames % s:
        raise ValueError(f"pool frame count {frames} must divide into "
                         f"{s} equal shard blocks")
    k_tot = int(reps) * idx.shape[0]
    if k_tot % (s * n_ports):
        raise ValueError(
            f"sharded burst needs {reps}*{idx.shape[0]} request lines to "
            f"split into {s} shard blocks of whole N={n_ports} groups — "
            f"bucket the live plan to a multiple of S*N")
    f_loc = frames // s
    k_loc = k_tot // s
    tiled = np.tile(idx, int(reps))                      # rep-major [k_tot]
    out_rows = np.nonzero(tiled < frames)[0]             # sentinels skip
    f = tiled[out_rows]
    rep = out_rows // idx.shape[0]
    owner = f // f_loc
    row_loc = rep * f_loc + f % f_loc                    # local line row
    req = out_rows // k_loc
    place_loc = out_rows % k_loc                         # local output row
    # stable-sort by (req, owner) to slot each request into its bucket
    key = req * s + owner
    order = np.argsort(key, kind="stable")
    key_s = key[order]
    _, start, counts = np.unique(key_s, return_index=True,
                                 return_counts=True)
    slot = np.arange(key_s.shape[0]) - np.repeat(start, counts)
    cap = max(int(counts.max()) if counts.size else 0, 1)
    cap = -(-cap // n_ports) * n_ports
    if cap_bucket:
        cap = -(-cap // cap_bucket) * cap_bucket
    fetch = np.full((s, s, cap), _SENTINEL, np.int32)
    place = np.full((s, s, cap), _SENTINEL, np.int32)
    ro, rq = owner[order], req[order]
    fetch[ro, rq, slot] = row_loc[order]
    place[rq, ro, slot] = place_loc[order]
    cross = int((owner != req).sum())
    return ShardPlan(fetch=fetch, place=place, k_tot=k_tot, cap=cap,
                     cross_frames=cross,
                     local_frames=int(out_rows.shape[0]) - cross)


def pool_partition_spec(leaf_ndim: int):
    """The ``PartitionSpec`` of a pool-backed KV leaf ``[lead...,
    n_pages, page_size, Hkv, D]``: the page axis shards over ``pool``,
    everything else replicates.  Derived from the leaf rank alone — the
    page axis is always fourth from the end."""
    from jax.sharding import PartitionSpec as P
    if leaf_ndim < 4:
        raise ValueError(f"pool leaf needs [*, pages, page, H, D], "
                         f"rank {leaf_ndim} is too small")
    spec = [None] * leaf_ndim
    spec[leaf_ndim - 4] = POOL_AXIS
    return P(*spec)


def make_pool_mesh(n_shards: int):
    """A 1-D ``("pool",)`` mesh over the first ``n_shards`` devices."""
    from repro.launch.mesh import compat_mesh
    devices = jax.devices()
    if len(devices) < n_shards:
        raise RuntimeError(
            f"pool mesh needs {n_shards} devices, have {len(devices)} — "
            f"set XLA_FLAGS=--xla_force_host_platform_device_count="
            f"{n_shards} before any jax import")
    return compat_mesh(devices[:n_shards], (n_shards,), (POOL_AXIS,))


def _exchange(x: jax.Array, collective: str) -> jax.Array:
    """One inter-shard hop: block ``j`` of ``x [S, ...]`` to shard ``j``."""
    from repro.parallel.collectives import ring_all_to_all, xla_all_to_all
    if collective == "ring":
        return ring_all_to_all(x, POOL_AXIS)
    return xla_all_to_all(x, POOL_AXIS)


def sharded_read_burst(fabric, stream: jax.Array, fetch: jax.Array,
                       place: jax.Array, k_tot: int) -> jax.Array:
    """Sparse read burst over the sharded pool: ``stream [R, F, N, W]``
    (page-major frames, pool axis sharded) → banked ``[k_tot//N, N, N, W]``
    (request order, sharded over groups) — bit-identical to the
    single-device ``Fabric.read_burst(lines, indices=)`` on the flattened
    ``[R*F, N, W]`` stream with rep-tiled indices.

    Two hops inside ``shard_map``: each shard fuse-gathers the rows
    ``fetch`` names from its local block (the PR-5 kernel when enabled),
    un-banks them to exchange order, runs one collective, and the
    requesting shard places the received lines at their output rows."""
    from repro.launch.mesh import compat_shard_map
    from jax.sharding import PartitionSpec as P
    n = fabric.n_ports
    s, _, cap = fetch.shape
    k_loc = k_tot // s
    collective = fabric.config.collective

    def body(loc, f, pl):
        lines = loc.reshape((-1,) + loc.shape[-2:])      # [R*F/S, N, W]
        banked = fabric.read_burst(lines, indices=f.reshape(s * cap))
        send = banked.swapaxes(1, 2).reshape(s, cap, n, -1)
        recv = _exchange(send, collective)               # [S(owner), cap, N, W]
        out = jnp.zeros((k_loc,) + recv.shape[-2:], recv.dtype)
        out = out.at[pl.reshape(s * cap)].set(
            recv.reshape(s * cap, n, -1), mode="drop")
        return out.reshape(k_loc // n, n, n, -1).swapaxes(1, 2)

    return compat_shard_map(
        body, mesh=fabric.mesh,
        in_specs=(P(None, POOL_AXIS), P(POOL_AXIS), P(POOL_AXIS)),
        out_specs=P(POOL_AXIS), check_vma=False)(stream, fetch, place)


def sharded_write_burst(fabric, banked: jax.Array, fetch: jax.Array,
                        place: jax.Array, into: jax.Array) -> jax.Array:
    """Write direction of :func:`sharded_read_burst`: banked live frames
    ``[k_tot//N, N, N, W]`` land at their pool rows of ``into [R, F, N,
    W]`` — the same ``fetch``/``place`` buckets run in reverse (each
    requestor sends its updated lines to the owning shard, which runs the
    fused scatter kernel into its local block).  Returns the updated
    stream; rows the indices never touch keep their frames without moving.
    This is also the disaggregation primitive: a prefill writer targeting a
    remote shard's pool is exactly this lowering."""
    from repro.launch.mesh import compat_shard_map
    from jax.sharding import PartitionSpec as P
    n = fabric.n_ports
    s, _, cap = fetch.shape
    collective = fabric.config.collective

    def body(bank_loc, into_loc, f, pl):
        k_loc = bank_loc.shape[0] * n
        lines = bank_loc.swapaxes(1, 2).reshape(k_loc, n, -1)
        send = jnp.take(lines, pl.reshape(s * cap), axis=0, mode="fill",
                        fill_value=0).reshape(s, cap, n, -1)
        recv = _exchange(send, collective)               # [S(req), cap, N, W]
        bank_recv = recv.reshape(s * cap // n, n, n, -1).swapaxes(1, 2)
        pool_lines = into_loc.reshape((-1,) + into_loc.shape[-2:])
        out = fabric.write_burst(bank_recv, indices=f.reshape(s * cap),
                                 into=pool_lines)
        return out.reshape(into_loc.shape)

    return compat_shard_map(
        body, mesh=fabric.mesh,
        in_specs=(P(POOL_AXIS), P(None, POOL_AXIS), P(POOL_AXIS),
                  P(POOL_AXIS)),
        out_specs=P(None, POOL_AXIS), check_vma=False)(
            banked, into, fetch, place)
