"""``repro.fabric`` — the unified memory-movement subsystem.

Every byte that changes layout in this framework moves through one of three
objects defined here, so the paper's interconnect is a *subsystem* rather
than per-consumer plumbing:

* :class:`Fabric` — the read/write data-transfer networks, the rectangular
  layout engine, the KV port-major application, and the explicit routing
  primitive, all behind one implementation switch;
* :class:`BurstScheduler` — multiplexes many logical streams (KV read, KV
  write, weight stream, MoE dispatch) through one network invocation per
  step, the framework form of the paper's §III-C burst buffering.  Streams
  pack along the word axis (each :class:`PortSpec` records its
  ``(offset, words)`` extent — the per-port head/tail pointers — and the
  network moves zero padding), sparse-extent streams fuse the paged pool's
  logical→physical gather into the burst itself (``gather=``/``scatter=``
  index operands; the network banks live frames, not the pool), and
  ``issue()``/``commit()`` split the transfer into the §III-C input/output
  double buffer so it overlaps consumer compute;
* :class:`PagedKVCache` — the serving engine's KV storage as fixed-size
  pages over the fabric's banked layout: a shared physical page pool
  (:class:`PagePool` — free-list allocation, per-slot logical→physical
  table, true reclamation) with gather-based decode, admission installed
  as ``prefill/*`` write-burst traffic, and the dense per-slot reservation
  kept as the A/B baseline (``FabricConfig.paged_pool``).

Paper-term ↔ API map
--------------------

=====================  =====================================================
Paper (Medusa)         ``repro.fabric``
=====================  =====================================================
``N`` (ports)          ``FabricConfig.n_ports`` (default: one per KV head)
``W_acc``              ``FabricConfig.lane_width`` (elements per port word;
                       default: ``head_dim``)
``W_line``             ``FabricConfig.line_width = n_ports * lane_width``
                       (one timestep across all KV heads)
transposition network  ``impl="medusa"`` — log₂(N)-stage binary exchange
(§III-A/B)             (rolls + selects; Pallas kernel on TPU)
crossbar baseline      ``impl="crossbar"`` — explicit index-gather routing
(§II)                  (over-provisioned, materialises index tensors)
semantics oracle       ``impl="oracle"`` — plain reshape/swapaxes
read network           ``Fabric.read``: line stream → banked port buffers
write network          ``Fabric.write``: banked port buffers → line stream
``MaxBurstLen``        ``FabricConfig.burst_len``; cycle model in
(§III-C)               ``repro.core.burst``; framework form in
                       ``BurstScheduler``
head/tail pointers     ``PortSpec.offset``/``.words`` — each stream's word
(§III-C)               extent in the packed burst (``FabricConfig.pack``)
I/O double buffer      ``BurstScheduler.issue()`` / ``.commit()`` — a
(§III-C)               one-deep pipeline; transfers overlap consumer compute
§III-E latency         ``Fabric.latency_cycles`` (= N)
=====================  =====================================================

All implementations are value-identical — the paper's resource/frequency
contrast becomes the lowered HLO (gather census, bytes accessed), which
``benchmarks/table2_resource.py`` and ``benchmarks/fabric_unified.py``
measure.  ``repro.core.interconnect.Interconnect`` remains as a thin
deprecated shim over :class:`Fabric`.
"""

from repro.configs.base import FabricConfig, PortSpec
from repro.fabric.fabric import Fabric
from repro.fabric.paged_kv import (PagedKVCache, PagePool, PageTable,
                                   SwapRecord)
from repro.fabric.scheduler import BurstScheduler, SchedulerStats
from repro.fabric.sharded import (ShardPlan, make_pool_mesh,
                                  pool_partition_spec, shard_plan)

__all__ = ["Fabric", "FabricConfig", "PortSpec", "BurstScheduler",
           "SchedulerStats", "PagedKVCache", "PagePool", "PageTable",
           "SwapRecord", "ShardPlan", "shard_plan", "pool_partition_spec",
           "make_pool_mesh"]
