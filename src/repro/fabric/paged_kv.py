"""Paged KV-cache layout for the serving engine.

The engine's batched decode cache is the fabric's banked layout applied to
time: slot ``s`` owns a deep-narrow region ``[t_max, Hkv, D]`` whose time
axis is divided into fixed-size **pages** of ``page_size`` timesteps — one
page = a burst of ``page_size`` DRAM lines (a line is one timestep across
the N = Hkv ports).  :class:`PagedKVCache` wraps the cache pytree with a
page table so slot refill is a **page remap**: admission writes only the
``ceil(prompt / page_size)`` pages the prompt occupies instead of splicing
the full ``t_max`` region (the seed engine's splice-copy), and retirement
just returns the slot's pages to the free accounting — the stale frames are
masked by per-slot positions and overwritten on the next admission.

Only full-depth attention leaves (names ``k``/``v`` with a ``t_max`` time
axis) are paged.  Ring (sliding-window) KV caches are written rolled by
prefill, so their window is copied whole; recurrent/SSM state leaves are
O(1) in time and also copied whole — both are the "control" traffic of the
fabric, small next to the paged KV payload.

``tokens_moved`` vs ``tokens_moved_dense`` quantifies the win: data actually
copied at admission vs what the dense splice would have copied.
"""

from __future__ import annotations

import dataclasses
from typing import List

import jax
import numpy as np


@dataclasses.dataclass
class PageTable:
    """Per-slot page accounting: ``used[s]`` pages hold valid tokens."""

    page_size: int
    pages_per_slot: int
    n_slots: int

    def __post_init__(self):
        self.used = np.zeros((self.n_slots,), np.int32)

    def pages_for(self, n_tokens: int) -> int:
        return min(-(-n_tokens // self.page_size), self.pages_per_slot)

    def map(self, slot: int, n_tokens: int) -> int:
        self.used[slot] = self.pages_for(n_tokens)
        return int(self.used[slot])

    def extend(self, slot: int, pos: int) -> None:
        """Decode grew the sequence to ``pos`` — map pages lazily."""
        self.used[slot] = max(self.used[slot],
                              self.pages_for(pos + 1))

    def free(self, slot: int) -> None:
        self.used[slot] = 0

    @property
    def occupancy(self) -> float:
        total = self.n_slots * self.pages_per_slot
        return float(self.used.sum()) / total if total else 0.0


class PagedKVCache:
    """A batched decode-cache pytree with paged admission.

    ``caches`` is whatever ``api.init_cache(cfg, max_slots, t_max)`` built;
    the wrapper never changes its structure (the jitted decode step consumes
    ``.caches`` directly), only how data moves into it.
    """

    def __init__(self, caches, max_slots: int, t_max: int, page_size: int):
        if page_size < 1:
            raise ValueError(f"page_size must be >= 1, got {page_size}")
        self.caches = caches
        self.max_slots = max_slots
        self.t_max = t_max
        self.table = PageTable(page_size=page_size,
                               pages_per_slot=-(-t_max // page_size),
                               n_slots=max_slots)
        self.tokens_moved = 0
        self.tokens_moved_dense = 0

    # -- admission: page remap instead of full splice -------------------------
    def refill(self, slot: int, req_cache, n_tokens: int) -> None:
        """Install a single-request cache into ``slot``, touching only the
        pages the ``n_tokens``-long prompt occupies."""
        pages = self.table.map(slot, n_tokens)
        span = min(pages * self.table.page_size, self.t_max)
        t_max, max_slots = self.t_max, self.max_slots

        def one(path, batch_leaf, req_leaf):
            name = _leaf_name(path)
            baxis = 1 if (batch_leaf.ndim >= 4
                          and batch_leaf.shape[1] == max_slots) else 0
            idx = [slice(None)] * batch_leaf.ndim
            idx[baxis] = slice(slot, slot + 1)
            taxis = baxis + 1
            if (name in ("k", "v") and batch_leaf.ndim > taxis
                    and batch_leaf.shape[taxis] == t_max):
                idx[taxis] = slice(0, span)
                req_idx = [slice(None)] * req_leaf.ndim
                req_idx[taxis] = slice(0, span)
                return batch_leaf.at[tuple(idx)].set(req_leaf[tuple(req_idx)])
            return batch_leaf.at[tuple(idx)].set(req_leaf)

        self.caches = jax.tree_util.tree_map_with_path(
            one, self.caches, req_cache)
        self.tokens_moved += span
        self.tokens_moved_dense += self.t_max

    # -- decode-time bookkeeping ----------------------------------------------
    def update(self, new_caches) -> None:
        """Adopt the cache pytree returned by the jitted decode step."""
        self.caches = new_caches

    def extend(self, slot: int, pos: int) -> None:
        self.table.extend(slot, pos)

    def free(self, slot: int) -> None:
        self.table.free(slot)


def _leaf_name(path) -> str:
    names: List[str] = [getattr(k, "key", getattr(k, "name", None))
                        for k in path
                        if hasattr(k, "key") or hasattr(k, "name")]
    return names[-1] if names else ""
