"""Paged KV-cache storage for the serving engine.

The engine's batched decode cache is the fabric's banked layout applied to
time: one timestep is a DRAM line across the N = Hkv ports, and the time
axis is divided into fixed-size **pages** of ``page_size`` timesteps — one
page = a burst of ``page_size`` lines.  Two storage modes share this module:

**Shared physical page pool** (``pool_pages > 0``, the engine default —
``FabricConfig.paged_pool``).  Every full-attention leaf is backed by one
``[n_pages, page_size, Hkv, D]`` physical region; a per-slot
logical→physical table (:class:`PagePool`, ``int32 [n_slots,
pages_per_slot]``, ``-1`` = unmapped) indirects each slot's time axis into
it.  Pages come from a free list at admission and decode growth and return
to it at retirement, so short and long sequences share HBM — a 12-token
prompt holds ``ceil(13/page_size)`` frames, not a ``t_max`` reservation —
and ``occupancy`` measures real frames.  Decode gathers each slot's mapped
pages through the page table (``models.lm`` — port-major, composed with the
step's shared read burst), bit-identical to the dense layout because every
valid position gathers exactly the frame the dense cache would hold.

**Dense per-slot reservation** (``pool_pages == 0``).  The original layout:
slot ``s`` owns ``[t_max, Hkv, D]`` and the page table only bounds the
admission splice — kept as the A/B baseline and bit-parity reference.

Admission rides the fabric: :meth:`PagedKVCache.admit_wave` stages each
admitted prompt's page-aligned KV extents as ``prefill/*`` write streams on
one :class:`repro.fabric.BurstScheduler` flush — the per-stream
``(offset, words)`` extents are exactly the page extents — so a wave of
admissions is **one write-network call per dtype** instead of per-layer
splices (``prefill_bursts``).  Under the fused-gather contract
(``fused_gather=True`` — ``FabricConfig.fused_gather``) the wave lowers as
**sparse-extent writes**: one scatter-indexed stream per paged leaf lands
every prompt's frames directly at their physical page rows through
``Fabric.write_burst(..., indices=, into=)`` (the indices ride the fused
burst kernel prefetched when kernels are enabled), replacing the host-side
page splice and widening burst eligibility to odd spans (sentinel pad rows
drop for free).  Otherwise slots whose extents miss the network geometry
(lines not a multiple of N, or a non-bankable fabric) fall back to the
per-layer splice (``prefill_splices``); the write network is an exact
round trip, so all installs are bit-identical.

Only full-depth attention leaves (``k``/``v`` with a ``t_max`` time axis —
the entries named by ``paged_entries``) are paged.  Ring (sliding-window)
KV caches are written rolled by prefill, so their window is copied whole;
recurrent/SSM state leaves are O(1) in time and also copied whole — both
are the "control" traffic of the fabric, small next to the paged payload.

``tokens_moved`` vs ``tokens_moved_dense`` quantifies the admission win:
timesteps actually copied vs what the seed engine's dense splice would have
copied — ``t_max`` for a slot's first occupant (the region's state is
unknown, the seed splices all of it), but only ``max(span, prior
occupant's extent)`` on reuse (a dense engine need only overwrite the
prompt plus the stale frames the prior occupant actually dirtied; counting
``t_max`` again overstated the baseline).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.fabric.fabric import pm_to_banked
from repro.fabric.scheduler import (FRAME_SENTINEL as _SENTINEL,
                                    BurstScheduler, SchedulerStats)


@dataclasses.dataclass
class PageTable:
    """Per-slot logical page accounting: ``used[s]`` pages hold valid tokens."""

    page_size: int
    pages_per_slot: int
    n_slots: int

    def __post_init__(self):
        self.used = np.zeros((self.n_slots,), np.int32)

    def pages_for(self, n_tokens: int) -> int:
        return min(-(-n_tokens // self.page_size), self.pages_per_slot)

    def map(self, slot: int, n_tokens: int) -> int:
        self.used[slot] = self.pages_for(n_tokens)
        return int(self.used[slot])

    def extend(self, slot: int, pos: int) -> None:
        """Decode grew the sequence to ``pos`` — map pages lazily."""
        self.used[slot] = max(self.used[slot],
                              self.pages_for(pos + 1))

    def free(self, slot: int) -> None:
        self.used[slot] = 0

    @property
    def occupancy(self) -> float:
        total = self.n_slots * self.pages_per_slot
        return float(self.used.sum()) / total if total else 0.0


class PagePool:
    """Shared physical page frames + the per-slot logical→physical table.

    ``table[s, p]`` is the physical page backing slot ``s``'s logical page
    ``p`` (``-1`` = unmapped).  Allocation pops the free list; retirement
    pushes a slot's pages back (true reclamation).  ``pages_allocated`` /
    ``pages_reclaimed`` are lifetime counters; ``pages_in_use`` and
    ``occupancy`` describe the pool right now.

    Under the pool-sharded lowering (``n_shards > 1``) the page ids split
    into ``n_shards`` contiguous blocks — block ``s`` lives on mesh shard
    ``s`` (matching ``PartitionSpec("pool")`` on the leaf's page axis) —
    and the free list becomes one stack per block with a round-robin
    allocation cursor, so a growing sequence's pages **stripe** across
    shards and a decode step's live-frame traffic balances instead of
    piling onto the first block.  ``n_shards=1`` is the seed allocator
    exactly (one stack, low ids first).
    """

    def __init__(self, page_size: int, n_pages: int, pages_per_slot: int,
                 n_slots: int, n_shards: int = 1):
        if page_size < 1 or n_pages < 1:
            raise ValueError(f"bad pool geometry page_size={page_size} "
                             f"n_pages={n_pages}")
        if n_shards < 1 or n_pages % n_shards:
            raise ValueError(
                f"pool of {n_pages} pages cannot split into {n_shards} "
                f"equal shard blocks")
        self.page_size = page_size
        self.n_pages = n_pages
        self.pages_per_slot = pages_per_slot
        self.n_slots = n_slots
        self.n_shards = n_shards
        self.table = np.full((n_slots, pages_per_slot), -1, np.int32)
        # one stack per shard block: low page ids allocate first within each
        # block (deterministic, test-friendly); allocation round-robins the
        # blocks so consecutive pages of one slot land on distinct shards
        sz = n_pages // n_shards
        self._free_by_shard: List[List[int]] = [
            list(range((s + 1) * sz - 1, s * sz - 1, -1))
            for s in range(n_shards)]
        self._rr = 0
        self.pages_allocated = 0
        self.pages_reclaimed = 0
        self.pages_swapped_out = 0
        self.pages_swapped_in = 0

    def shard_of(self, page: int) -> int:
        """The mesh shard owning physical page ``page`` (contiguous blocks)."""
        return page // (self.n_pages // self.n_shards)

    @property
    def free_pages(self) -> int:
        return sum(len(s) for s in self._free_by_shard)

    @property
    def free_pages_by_shard(self) -> Tuple[int, ...]:
        """Free pages per shard block — the striping balance census."""
        return tuple(len(s) for s in self._free_by_shard)

    @property
    def pages_in_use(self) -> int:
        return self.n_pages - self.free_pages

    @property
    def occupancy(self) -> float:
        return self.pages_in_use / self.n_pages

    def mapped(self, slot: int) -> int:
        return int((self.table[slot] >= 0).sum())

    def ensure(self, slot: int, n_logical: int) -> List[Tuple[int, int]]:
        """Map logical pages ``[0, n_logical)`` of ``slot``; returns the
        newly mapped ``(logical, physical)`` pairs.  Raises on exhaustion —
        admission gates on :meth:`free_pages`, so this firing mid-decode
        means the pool was sized below the workload's live footprint."""
        n_logical = min(n_logical, self.pages_per_slot)
        new = []
        for p in range(n_logical):
            if self.table[slot, p] < 0:
                phys = self._alloc_one()
                if phys < 0:
                    raise RuntimeError(
                        f"page pool exhausted: slot {slot} needs logical page "
                        f"{p} but all {self.n_pages} physical pages are "
                        f"mapped — size the pool for the live footprint or "
                        f"admit fewer sequences")
                self.table[slot, p] = phys
                self.pages_allocated += 1
                new.append((p, phys))
        return new

    def _alloc_one(self) -> int:
        """Pop one page, round-robin over the shard blocks (skipping empty
        ones); -1 when the whole pool is exhausted.  One shard: seed
        stack-pop exactly."""
        for _ in range(self.n_shards):
            stack = self._free_by_shard[self._rr]
            self._rr = (self._rr + 1) % self.n_shards
            if stack:
                return stack.pop()
        return -1

    def release(self, slot: int) -> int:
        """Return every page mapped by ``slot`` to its owning shard's free
        stack (reversed table order, so the earliest-allocated page tops its
        stack again — the seed LIFO order per block)."""
        phys = self.table[slot][self.table[slot] >= 0]
        sz = self.n_pages // self.n_shards
        for p in phys[::-1]:
            self._free_by_shard[int(p) // sz].append(int(p))
        self.table[slot] = -1
        self.pages_reclaimed += len(phys)
        return len(phys)

    def swap_out(self, slot: int) -> int:
        """Victim eviction: return the slot's physical pages to the free
        list after its frames were staged to the host swap space.  Built on
        :meth:`release`, so the conservation counters stay balanced; the
        ``pages_swapped_*`` counters are the swap-traffic census."""
        n = self.release(slot)
        self.pages_swapped_out += n
        return n

    def swap_in(self, slot: int, n_pages: int) -> List[Tuple[int, int]]:
        """Re-map an evicted slot's ``n_pages`` logical pages from the free
        list.  The pages come up wherever the allocator finds them — the
        scatter restore addresses the new physical rows, so placement is
        free to differ from the pre-eviction mapping."""
        new = self.ensure(slot, n_pages)
        self.pages_swapped_in += len(new)
        return new

    def check(self) -> None:
        """Free-list conservation: every physical page is exactly once in
        the free lists or the table, the lifetime counters balance, and —
        per shard — each block's free stack holds only its own pages and
        the block's mapped + free pages are exactly its id range."""
        mapped = self.table[self.table >= 0].tolist()
        if len(mapped) != len(set(mapped)):
            raise ValueError(f"double-mapped physical pages: {sorted(mapped)}")
        free = [p for stack in self._free_by_shard for p in stack]
        if sorted(mapped + free) != list(range(self.n_pages)):
            raise ValueError(
                f"page leak: mapped={sorted(mapped)} free={sorted(free)}"
                f" != range({self.n_pages})")
        sz = self.n_pages // self.n_shards
        for s, stack in enumerate(self._free_by_shard):
            foreign = [p for p in stack if p // sz != s]
            if foreign:
                raise ValueError(
                    f"shard {s} free stack holds foreign pages {foreign}")
            block_mapped = [p for p in mapped if p // sz == s]
            if sorted(block_mapped + stack) != list(range(s * sz,
                                                          (s + 1) * sz)):
                raise ValueError(
                    f"shard {s} conservation broken: mapped="
                    f"{sorted(block_mapped)} free={sorted(stack)}")
        if self.pages_allocated - self.pages_reclaimed != len(mapped):
            raise ValueError(
                f"counter drift: allocated={self.pages_allocated} "
                f"reclaimed={self.pages_reclaimed} in_use={len(mapped)}")


@dataclasses.dataclass
class SwapRecord:
    """Host swap-space image of an evicted slot.

    ``frames`` holds each paged leaf's mapped frames as line-major host
    arrays (``[reps * span, Hkv, D]`` — the exact bytes the read network
    staged out); ``unpaged`` holds the slot slices of every non-paged leaf
    (ring windows, recurrent state).  ``mapped`` is the physical page count
    to re-map on swap-in; ``used_pages`` / ``dirty`` restore the logical
    page table and the dense-splice counterfactual."""

    mapped: int
    used_pages: int
    dirty: int
    frames: Dict[Tuple[str, int, str], np.ndarray]
    unpaged: Dict[str, np.ndarray]


class PagedKVCache:
    """A batched decode-cache pytree with paged admission and (optionally)
    shared-pool physical storage.

    ``caches`` is whatever ``api.init_cache(...)`` built — dense per-slot
    regions, or pool-backed paged leaves when it was built with
    ``pool_pages > 0`` (then pass the same ``pool_pages`` here, plus
    ``paged_entries`` — the ``(kind, index)`` cache entries that are paged,
    from :func:`repro.models.lm.paged_entries` — and the engine's
    :class:`~repro.fabric.Fabric` so admission can ride the write network).
    The wrapper never changes the pytree structure (the jitted decode step
    consumes ``.caches`` directly), only how data moves into it.
    """

    def __init__(self, caches, max_slots: int, t_max: int, page_size: int,
                 pool_pages: int = 0, paged_entries=(), fabric=None,
                 fused_gather: bool = False, pool_shards: int = 1):
        if page_size < 1:
            raise ValueError(f"page_size must be >= 1, got {page_size}")
        self.fused_gather = fused_gather
        self.caches = caches
        self.max_slots = max_slots
        self.t_max = t_max
        self.table = PageTable(page_size=page_size,
                               pages_per_slot=-(-t_max // page_size),
                               n_slots=max_slots)
        self.pool = (PagePool(page_size, pool_pages,
                              self.table.pages_per_slot, max_slots,
                              n_shards=pool_shards)
                     if pool_pages else None)
        self.paged_entries = tuple(paged_entries)
        self.fabric = fabric
        self.tokens_moved = 0
        self.tokens_moved_dense = 0
        self.prefill_bursts = 0
        self.prefill_splices = 0
        # per-slot dirty extent (timesteps the slot's occupants ever wrote):
        # -1 = never occupied.  This is the dense-splice counterfactual the
        # seed engine would pay on refill (see module docstring).
        self._dirty = np.full((max_slots,), -1, np.int64)
        # serving-path fault seam: when set, swap transfers consult it for
        # injected in-flight corruption (caught by the parity check)
        self.fault_injector = None

    # -- geometry / accounting -------------------------------------------------
    @property
    def paged(self) -> bool:
        """True when KV storage is the shared physical page pool."""
        return self.pool is not None

    @property
    def occupancy(self) -> float:
        """Fraction of physical frames in use (pool) / logical pages used
        against the dense reservation (dense mode)."""
        return self.pool.occupancy if self.pool else self.table.occupancy

    @property
    def dense_reserved_pages(self) -> int:
        """Pages the dense layout reserves regardless of occupancy."""
        return self.max_slots * self.table.pages_per_slot

    def page_table_device(self) -> jax.Array:
        """The logical→physical table as a device operand for the gather-
        based decode step (``int32 [max_slots, pages_per_slot]``)."""
        if self.pool is None:
            raise ValueError("dense mode has no physical page table")
        return jnp.asarray(self.pool.table)

    def _count_refill(self, slot: int, span: int) -> None:
        self.tokens_moved += span
        prior = int(self._dirty[slot])
        # the seed engine's dense splice: the whole unknown region on first
        # fill, prompt + the prior occupant's stale frames on reuse
        self.tokens_moved_dense += self.t_max if prior < 0 else max(span, prior)
        self._dirty[slot] = span

    # -- admission -------------------------------------------------------------
    def refill(self, slot: int, req_cache, n_tokens: int) -> None:
        """Install a single request (splice path); see :meth:`admit_wave`."""
        self.admit_wave([(slot, req_cache, n_tokens)], burst=False)

    def admit_wave(self, entries: Sequence[Tuple[int, object, int]],
                   stats: Optional[SchedulerStats] = None,
                   burst: Optional[bool] = None) -> None:
        """Install a wave of admitted prompts: ``entries`` is
        ``[(slot, req_cache, n_tokens), ...]``.

        Pool mode stages every slot's page-aligned KV extents as
        ``prefill/*`` write streams on one scheduler flush (1 write-network
        call per dtype for the whole wave); slots off the network geometry —
        and every slot when ``burst=False`` or the fabric can't bank —
        install by per-layer splice instead, bit-identically.  Dense mode
        always splices (it is the baseline layout)."""
        plans = []
        for slot, req_cache, n_tokens in entries:
            inst_pages = self.table.pages_for(n_tokens)
            span = min(inst_pages * self.table.page_size, self.t_max)
            self._count_refill(slot, span)
            self.table.map(slot, n_tokens)
            if self.pool is not None:
                self.pool.ensure(slot, self.table.pages_for(n_tokens + 1))
            plans.append((slot, req_cache, span))
        if self.pool is None:
            for slot, req_cache, span in plans:
                self._dense_splice(slot, req_cache, span)
            return
        self._pool_install(plans, stats=stats, burst=burst)

    # -- decode-time bookkeeping ----------------------------------------------
    def update(self, new_caches) -> None:
        """Adopt the cache pytree returned by the jitted decode step."""
        self.caches = new_caches

    def extend(self, slot: int, pos: int) -> None:
        self.table.extend(slot, pos)
        self._dirty[slot] = max(int(self._dirty[slot]), pos)
        if self.pool is not None:
            self.pool.ensure(slot, self.table.pages_for(pos + 1))

    def free(self, slot: int) -> None:
        """Retire the slot: logical pages clear and — in pool mode — the
        physical pages return to the free list (true reclamation).  The
        dirty-extent counterfactual survives retirement: the dense engine's
        stale frames don't vanish when a request finishes."""
        self.table.free(slot)
        if self.pool is not None:
            self.pool.release(slot)

    # -- swap (graceful degradation under oversubscription) --------------------
    def swap_out(self, slot: int,
                 stats: Optional[SchedulerStats] = None) -> SwapRecord:
        """Evict ``slot`` to the host swap space: stage every mapped frame
        out over the read network's fused page-table gather — one
        ``swap/<slot>/<leaf>`` sparse-extent stream per paged leaf, one
        flush for the slot — then free the physical pages.  Returns the
        record :meth:`swap_in` consumes.  The transfer is parity-checked
        end to end (and retried once on mismatch), so the round trip is
        bit-exact like every other fabric stream."""
        if self.pool is None:
            raise ValueError("swap requires the shared page pool")
        record = SwapRecord(mapped=self.pool.mapped(slot),
                            used_pages=int(self.table.used[slot]),
                            dirty=int(self._dirty[slot]),
                            frames={}, unpaged=self._extract_unpaged(slot))
        if record.mapped:
            pf = self._phys_frames(slot, record.mapped * self.table.page_size)
            if self._fused_eligible():
                record.frames = self._swap_gather(slot, pf, stats)
            else:
                # off the network geometry: direct host-side stage (the
                # splice fallback — still bit-exact, just not burst traffic)
                record.frames = {
                    (kind, i, name): np.asarray(jnp.take(
                        _flat_frames_lines(self.caches[kind][i][name]),
                        jnp.asarray(self._rep_idx(kind, i, pf)), axis=0))
                    for kind, i in self.paged_entries
                    for name in ("k", "v")}
        self.table.free(slot)
        self.pool.swap_out(slot)
        return record

    def swap_in(self, slot: int, record: SwapRecord,
                stats: Optional[SchedulerStats] = None) -> None:
        """Re-admit an evicted slot: re-map physical pages from the free
        list and restore the host image — the write network's scatter lands
        every frame at its new physical row.  One flush per slot, so two
        concurrent restores never scatter into the same pool leaf in one
        network call."""
        if self.pool is None:
            raise ValueError("swap requires the shared page pool")
        self.pool.swap_in(slot, record.mapped)
        self.table.used[slot] = record.used_pages
        self._dirty[slot] = record.dirty
        if record.mapped:
            span = record.mapped * self.table.page_size
            pf = self._phys_frames(slot, span)
            if self._fused_eligible():
                self._swap_scatter(slot, pf, record.frames, stats)
            else:
                for (kind, i, name), lines in record.frames.items():
                    pool_leaf = self.caches[kind][i][name]
                    lead = pool_leaf.shape[:-4]
                    frames = jnp.asarray(lines).reshape(
                        lead + (span,) + pool_leaf.shape[-2:])
                    leaf = _install_pool_leaf(pool_leaf, frames,
                                              self.pool.table[slot], span,
                                              self.table.page_size)
                    self._set_leaf(kind, i, name, leaf)
        self._restore_unpaged(slot, record.unpaged)

    def _phys_frames(self, slot: int, span: int) -> np.ndarray:
        """Physical frame rows backing the slot's first ``span`` timesteps
        (the page-table indirection, host-side)."""
        ps = self.table.page_size
        row = self.pool.table[slot]
        t = np.arange(span)
        return (row[t // ps].astype(np.int64) * ps + t % ps).astype(np.int32)

    def _rep_idx(self, kind: str, i: int, pf: np.ndarray) -> np.ndarray:
        """Physical frame rows ``pf`` rep-tiled across a leaf's lead dims —
        the flattened-line addresses of one slot's frames in that leaf."""
        pool_leaf = self.caches[kind][i]["k"]
        frames_n = pool_leaf.shape[-4] * pool_leaf.shape[-3]
        reps = int(np.prod(pool_leaf.shape[:-4])) if pool_leaf.ndim > 4 else 1
        return (np.arange(reps, dtype=np.int64)[:, None] * frames_n
                + pf[None, :]).reshape(-1).astype(np.int32)

    def _swap_gather(self, slot: int, pf: np.ndarray, stats) -> Dict:
        """Swap-out data path: every paged leaf's mapped frames as one
        gather-indexed read stream (sentinel-padded to the port width)."""
        n = self.fabric.n_ports
        streams = {(kind, i, name): (self._rep_idx(kind, i, pf),
                                     _flat_frames_lines(
                                         self.caches[kind][i][name]))
                   for kind, i in self.paged_entries for name in ("k", "v")}
        expect = 0
        for idx, src in streams.values():
            expect ^= _parity_word(jnp.take(src, jnp.asarray(idx), axis=0))

        def transfer():
            sched = BurstScheduler(self.fabric, stats=stats)
            for (kind, i, name), (idx, src) in streams.items():
                pad = (-idx.shape[0]) % n
                gidx = (np.concatenate(
                    [idx, np.full((pad,), _SENTINEL, np.int32)])
                    if pad else idx)
                sched.enqueue_read(f"swap/{slot}/{kind}{i}/{name}", src,
                                   gather=jnp.asarray(gidx))
            out = sched.flush()
            got = {}
            for (kind, i, name), (idx, _) in streams.items():
                lines = _banked_to_lines(out[f"swap/{slot}/{kind}{i}/{name}"])
                got[(kind, i, name)] = np.asarray(lines[: idx.shape[0]])
            return got, got

        got = self._checked_transfer(transfer, expect, stats)
        if stats is not None:
            stats.swap_bursts += 1
            stats.swap_out_words += sum(v.size for v in got.values())
        return got

    def _swap_scatter(self, slot: int, pf: np.ndarray, frames: Dict,
                      stats) -> None:
        """Swap-in data path: every paged leaf's saved frames as one
        scatter-indexed write stream landing at the new physical rows."""
        n = self.fabric.n_ports
        expect = 0
        for lines in frames.values():
            expect ^= _parity_word(lines)

        def transfer():
            sched = BurstScheduler(self.fabric, stats=stats)
            targets = {}
            for (kind, i, name), lines in sorted(frames.items()):
                idx = self._rep_idx(kind, i, pf)
                ln = jnp.asarray(lines)
                pad = (-idx.shape[0]) % n
                sidx = idx
                if pad:
                    ln = jnp.pad(ln, ((0, pad), (0, 0), (0, 0)))
                    sidx = np.concatenate(
                        [idx, np.full((pad,), _SENTINEL, np.int32)])
                pool_leaf = self.caches[kind][i][name]
                tag = f"swap/{slot}/{kind}{i}/{name}"
                sched.enqueue_write(tag, _lines_to_banked(ln, n),
                                    scatter=jnp.asarray(sidx),
                                    into=_flat_frames_lines(pool_leaf))
                targets[tag] = (kind, i, name, pool_leaf.shape, idx)
            out = sched.flush()
            leaves, received = {}, {}
            for tag, (kind, i, name, shape, idx) in targets.items():
                received[tag] = np.asarray(jnp.take(
                    out[tag], jnp.asarray(idx), axis=0))
                leaves[tag] = (kind, i, name, out[tag].reshape(shape))
            return leaves, received

        leaves = self._checked_transfer(transfer, expect, stats)
        for kind, i, name, leaf in leaves.values():
            self._set_leaf(kind, i, name, leaf)
        if stats is not None:
            stats.swap_bursts += 1
            stats.swap_in_words += sum(v.size for v in frames.values())

    def _checked_transfer(self, transfer, expect: int, stats):
        """Run a swap transfer under the end-to-end parity word: XOR of
        every byte the receiver staged must match the sender's.  The
        networks are exact, so only injected corruption trips it; a
        mismatch discards the staged copy and retries once (the injector's
        ordinal does not re-fire on the retry)."""
        inj = self.fault_injector
        for attempt in (0, 1):
            payload, received = transfer()
            if inj is not None and inj.corrupt_swap_burst(attempt):
                key = sorted(received)[0]
                bad = received[key].copy()
                bad.view(np.uint8).flat[0] ^= 0xFF
                received[key] = bad
            parity = 0
            for v in received.values():
                parity ^= _parity_word(v)
            if parity == expect:
                return payload
            if stats is not None:
                stats.bursts_retried += 1
        raise RuntimeError(
            "swap transfer failed the parity check twice — giving up")

    def _extract_unpaged(self, slot: int) -> Dict[str, np.ndarray]:
        """Host copies of the slot's non-paged leaf slices (ring windows,
        recurrent state) — the control-traffic half of the swap image."""
        paged = set(self.paged_entries)
        max_slots = self.max_slots
        out: Dict[str, np.ndarray] = {}

        def one(path, batch_leaf):
            kind, i, name = _leaf_entry(path)
            if (kind, i) not in paged or name not in ("k", "v"):
                baxis = 1 if (batch_leaf.ndim >= 4
                              and batch_leaf.shape[1] == max_slots) else 0
                idx = [slice(None)] * batch_leaf.ndim
                idx[baxis] = slice(slot, slot + 1)
                out[jax.tree_util.keystr(path)] = np.asarray(
                    batch_leaf[tuple(idx)])
            return batch_leaf

        jax.tree_util.tree_map_with_path(one, self.caches)
        return out

    def _restore_unpaged(self, slot: int, saved: Dict[str, np.ndarray]):
        max_slots = self.max_slots

        def one(path, batch_leaf):
            key = jax.tree_util.keystr(path)
            if key not in saved:
                return batch_leaf
            baxis = 1 if (batch_leaf.ndim >= 4
                          and batch_leaf.shape[1] == max_slots) else 0
            idx = [slice(None)] * batch_leaf.ndim
            idx[baxis] = slice(slot, slot + 1)
            return batch_leaf.at[tuple(idx)].set(jnp.asarray(saved[key]))

        self.caches = jax.tree_util.tree_map_with_path(one, self.caches)

    # -- install paths ---------------------------------------------------------
    def _dense_splice(self, slot: int, req_cache, span: int) -> None:
        """Dense-mode install: splice the request cache into the slot's
        reserved region, paged leaves bounded to ``span`` timesteps."""
        t_max, max_slots = self.t_max, self.max_slots

        def one(path, batch_leaf, req_leaf):
            name = _leaf_name(path)
            baxis = 1 if (batch_leaf.ndim >= 4
                          and batch_leaf.shape[1] == max_slots) else 0
            idx = [slice(None)] * batch_leaf.ndim
            idx[baxis] = slice(slot, slot + 1)
            taxis = baxis + 1
            if (name in ("k", "v") and batch_leaf.ndim > taxis
                    and batch_leaf.shape[taxis] == t_max):
                idx[taxis] = slice(0, span)
                req_idx = [slice(None)] * req_leaf.ndim
                req_idx[taxis] = slice(0, span)
                return batch_leaf.at[tuple(idx)].set(req_leaf[tuple(req_idx)])
            return batch_leaf.at[tuple(idx)].set(req_leaf)

        self.caches = jax.tree_util.tree_map_with_path(
            one, self.caches, req_cache)

    def _req_frames(self, req_cache, kind: str, i: int, name: str,
                    span: int) -> jax.Array:
        """A request's first ``span`` timesteps of one paged leaf, as
        line-major frames ``[lead..., span, Hkv, D]``."""
        leaf = req_cache[kind][i][name]        # [lead..., 1, t_alloc, Hkv, D]
        return leaf[..., 0, :span, :, :]

    def _burst_eligible(self, req_cache, span: int) -> bool:
        """Whether a slot's page extents fit the write network: a bankable
        fabric on the port-per-KV-head geometry, and every paged leaf's
        line count a multiple of N."""
        if self.fabric is None or not self.fabric.banks_kv:
            return False
        n = self.fabric.n_ports
        for kind, i in self.paged_entries:
            leaf = req_cache[kind][i]["k"]
            hkv = leaf.shape[-2]
            lead = int(np.prod(leaf.shape[:-4])) if leaf.ndim > 4 else 1
            if hkv != n or (lead * span) % n:
                return False
        return True

    def _fused_eligible(self) -> bool:
        """Whether the fused-gather install can carry this pool's admission:
        a bankable fabric on the port-per-KV-head geometry.  Per-slot span
        alignment no longer matters — sparse writes pad odd spans with
        sentinel rows (dropped on scatter), so slots the banked install had
        to splice now ride the burst too."""
        if self.fabric is None or not self.fabric.banks_kv:
            return False
        n = self.fabric.n_ports
        return all(self.caches[kind][i]["k"].shape[-2] == n
                   for kind, i in self.paged_entries)

    def _pool_install_fused(self, plans, stats=None) -> None:
        """Fused-contract install: each paged leaf takes the whole wave as
        ONE sparse-extent write stream — the write network reassembles every
        admitted prompt's frames and the scatter lands each at its physical
        page row (``Fabric.write_burst(..., indices=, into=)``; the indices
        ride the fused burst kernel prefetched when kernels are enabled).
        Still one flush and one network call per dtype per wave, and the
        scatter replaces the host-side ``_install_pool_leaf`` postprocess."""
        n = self.fabric.n_ports
        ps = self.table.page_size
        staged: Dict[Tuple[str, int, str], Tuple[list, list]] = {}
        for slot, req_cache, span in plans:
            if span == 0:
                continue
            row = self.pool.table[slot]
            t = np.arange(span)
            pf = (row[t // ps].astype(np.int64) * ps + t % ps).astype(np.int32)
            for kind, i in self.paged_entries:
                pool_leaf = self.caches[kind][i]["k"]
                frames_n = pool_leaf.shape[-4] * pool_leaf.shape[-3]
                reps = int(np.prod(pool_leaf.shape[:-4])) \
                    if pool_leaf.ndim > 4 else 1
                idx = (np.arange(reps, dtype=np.int64)[:, None] * frames_n
                       + pf[None, :]).reshape(-1).astype(np.int32)
                for name in ("k", "v"):
                    fr = self._req_frames(req_cache, kind, i, name, span)
                    lines = fr.reshape(-1, n, fr.shape[-1])
                    lns, idxs = staged.setdefault((kind, i, name), ([], []))
                    lns.append(lines)
                    idxs.append(idx)
        if staged:
            sched = BurstScheduler(self.fabric, stats=stats)
            targets = {}
            for (kind, i, name), (lns, idxs) in staged.items():
                lines = (lns[0] if len(lns) == 1
                         else jnp.concatenate(lns, axis=0))
                idx = np.concatenate(idxs)
                pad = (-lines.shape[0]) % n
                if pad:
                    lines = jnp.pad(lines, ((0, pad), (0, 0), (0, 0)))
                    idx = np.concatenate(
                        [idx, np.full((pad,), _SENTINEL, np.int32)])
                pool_leaf = self.caches[kind][i][name]
                into = _flat_frames_lines(pool_leaf)
                tag = f"prefill/{kind}{i}/{name}"
                sched.enqueue_write(tag, _lines_to_banked(lines, n),
                                    scatter=jnp.asarray(idx), into=into)
                targets[tag] = (kind, i, name, pool_leaf.shape)
            out = sched.flush()
            for tag, (kind, i, name, shape) in targets.items():
                self._set_leaf(kind, i, name, out[tag].reshape(shape))
            self.prefill_bursts += 1
            if stats is not None:
                stats.prefill_bursts += 1
        for slot, req_cache, _ in plans:
            self._splice_unpaged(slot, req_cache)

    def _pool_install(self, plans, stats=None, burst=None) -> None:
        """Install a wave into the shared pool: under the fused-gather
        contract the whole wave is sparse-extent write traffic
        (:meth:`_pool_install_fused`); otherwise burst-eligible slots ride
        one write-network flush and the rest splice per leaf."""
        if self.fused_gather and burst is not False and self._fused_eligible():
            self._pool_install_fused(plans, stats=stats)
            return
        n = self.fabric.n_ports if self.fabric is not None else 0
        # burst=False forces the splice; True/None burst wherever the slot's
        # extents fit the network geometry (a forced True cannot override it)
        use_burst = {slot: burst is not False
                     and self._burst_eligible(rc, span)
                     for slot, rc, span in plans}
        moved: Dict[str, jax.Array] = {}
        staged = []
        sched = None
        for slot, req_cache, span in plans:
            if not use_burst[slot] or span == 0:
                continue
            if sched is None:
                sched = BurstScheduler(self.fabric, stats=stats)
            for kind, i in self.paged_entries:
                for name in ("k", "v"):
                    frames = self._req_frames(req_cache, kind, i, name, span)
                    d = frames.shape[-1]
                    lines = frames.reshape(-1, n, d)
                    tag = f"prefill/{slot}/{kind}{i}/{name}"
                    sched.enqueue_write(tag, _lines_to_banked(lines, n))
                    staged.append((tag, frames.shape))
        if sched is not None:
            sched.issue()
            out = sched.commit()
            moved = {tag: out[tag].reshape(shape) for tag, shape in staged}
            self.prefill_bursts += 1
            if stats is not None:
                stats.prefill_bursts += 1
        for slot, req_cache, span in plans:
            if span and not use_burst[slot]:
                self.prefill_splices += 1
            for kind, i in self.paged_entries:
                for name in ("k", "v"):
                    tag = f"prefill/{slot}/{kind}{i}/{name}"
                    frames = (moved[tag] if tag in moved else
                              self._req_frames(req_cache, kind, i, name, span))
                    leaf = _install_pool_leaf(
                        self.caches[kind][i][name], frames,
                        self.pool.table[slot], span, self.table.page_size)
                    self._set_leaf(kind, i, name, leaf)
            self._splice_unpaged(slot, req_cache)

    def _set_leaf(self, kind: str, i: int, name: str, leaf) -> None:
        entry = dict(self.caches[kind][i])
        entry[name] = leaf
        seq = list(self.caches[kind])
        seq[i] = entry
        self.caches = {**self.caches, kind: seq}

    def _splice_unpaged(self, slot: int, req_cache) -> None:
        """Install the non-paged leaves (ring windows, recurrent/SSM state)
        into the slot's dense batch axis — the fabric's control traffic."""
        paged = set(self.paged_entries)
        max_slots = self.max_slots

        def one(path, batch_leaf, req_leaf):
            kind, i, name = _leaf_entry(path)
            if (kind, i) in paged and name in ("k", "v"):
                return batch_leaf
            baxis = 1 if (batch_leaf.ndim >= 4
                          and batch_leaf.shape[1] == max_slots) else 0
            idx = [slice(None)] * batch_leaf.ndim
            idx[baxis] = slice(slot, slot + 1)
            return batch_leaf.at[tuple(idx)].set(req_leaf)

        self.caches = jax.tree_util.tree_map_with_path(
            one, self.caches, req_cache)


def _lines_to_banked(lines: jax.Array, n: int) -> jax.Array:
    """Line-major frames ``[L, N, D]`` → the banked ``[G, N, N, D]`` buffer
    whose write-network image is exactly ``lines`` (write ∘ bank is the
    identity — the accelerator side holds port-major head streams and the
    write network reassembles the wide DRAM lines)."""
    return pm_to_banked(jnp.swapaxes(lines, 0, 1), n)    # [N, L, D] streams


def _banked_to_lines(banked: jax.Array) -> jax.Array:
    """Inverse relabel of :func:`_lines_to_banked`: the banked
    ``[G, N, N, D]`` image a gather read returns, back as line-major frames
    ``[G*N, N, D]`` in request order (sentinel pad rows land at the tail)."""
    g, n, _, d = banked.shape
    pm = banked.transpose(1, 0, 2, 3).reshape(n, g * n, d)
    return jnp.swapaxes(pm, 0, 1)


def _parity_word(arr) -> int:
    """XOR of every byte — the end-to-end checksum on swap transfers."""
    a = np.ascontiguousarray(np.asarray(arr))
    if a.size == 0:
        return 0
    return int(np.bitwise_xor.reduce(a.view(np.uint8), axis=None))


def _flat_frames_lines(pool_leaf: jax.Array) -> jax.Array:
    """Pool leaf ``[lead..., n_pages, page_size, Hkv, D]`` → its flattened
    line stream ``[lead*F, Hkv, D]`` (the sparse scatter's target).  Must
    stay the composition ``kv_leaf_to_lines(_flat_frames(leaf))`` that the
    decode step uses (``models/lm.py``) — admission's scatter rows and the
    decode bursts address the same line ordering; the pair lives model-side
    and fabric sits below models, hence this mirror."""
    flat = pool_leaf.reshape(pool_leaf.shape[:-4] + (-1,)
                             + pool_leaf.shape[-2:])
    return flat.reshape((-1,) + flat.shape[-2:])


def _install_pool_leaf(pool_leaf: jax.Array, frames: jax.Array,
                       table_row: np.ndarray, span: int,
                       page_size: int) -> jax.Array:
    """Scatter a prompt's ``span`` line-major frames into the physical pages
    ``table_row`` maps (full pages in one vectorized set, plus the partial
    tail page).  Indices are host-side ints — admission is eager."""
    if span == 0:
        return pool_leaf
    page_axis = pool_leaf.ndim - 4
    n_full, tail = divmod(span, page_size)
    n_pages_used = n_full + (1 if tail else 0)
    phys = [int(table_row[p]) for p in range(n_pages_used)]
    lead = frames.shape[:-3]
    if n_full:
        data = frames[..., : n_full * page_size, :, :].reshape(
            lead + (n_full, page_size) + frames.shape[-2:])
        idx = [slice(None)] * pool_leaf.ndim
        idx[page_axis] = np.asarray(phys[:n_full])
        pool_leaf = pool_leaf.at[tuple(idx)].set(data)
    if tail:
        idx = [slice(None)] * pool_leaf.ndim
        idx[page_axis] = phys[-1]
        idx[page_axis + 1] = slice(0, tail)
        pool_leaf = pool_leaf.at[tuple(idx)].set(
            frames[..., n_full * page_size:, :, :])
    return pool_leaf


def _leaf_name(path) -> str:
    names: List[str] = [getattr(k, "key", getattr(k, "name", None))
                        for k in path
                        if hasattr(k, "key") or hasattr(k, "name")]
    return names[-1] if names else ""


def _leaf_entry(path) -> Tuple[str, int, str]:
    """``(kind, index, leaf_name)`` of a cache-tree path, e.g.
    ``("unit", 0, "k")``."""
    kind = idx = name = None
    for k in path:
        if hasattr(k, "key"):
            if kind is None:
                kind = k.key
            else:
                name = k.key
        elif hasattr(k, "idx") and idx is None:
            idx = k.idx
    return kind, idx if idx is not None else -1, name or ""
