"""Burst scheduler: many logical streams, one network invocation per step.

The paper's burst machinery (§III-C: MaxBurstLen-deep banks, per-port
head/tail pointers, interference-free progress — modelled cycle-by-cycle in
:mod:`repro.core.burst`) exists so that *independent* traffic shares one
physical transposition network.  This module is the framework-level
generalisation: consumers (KV read, KV write, weight stream, MoE expert
dispatch) declare logical streams against a shared :class:`Fabric`; at each
step the scheduler concatenates every queued stream into one burst, runs the
read (resp. write) network **once**, and hands each consumer its slice back.

Value identity is exact: the read network transposes each N-line group
independently, every stream contributes whole groups, and narrower streams
are zero-padded on the word axis and sliced back after the transfer (the
words of a line move independently through the network).  Streams of
different dtypes cannot share a burst bit-identically, so the scheduler
keeps one burst per dtype.

``stats`` counts network invocations vs streams served, which is exactly the
contrast ``benchmarks/fabric_unified.py`` measures against per-consumer
:class:`Fabric` calls.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import PortSpec
from repro.fabric.fabric import Fabric


@dataclasses.dataclass
class SchedulerStats:
    streams_served: int = 0
    network_calls: int = 0

    @property
    def calls_saved(self) -> int:
        return self.streams_served - self.network_calls


@dataclasses.dataclass
class _Queued:
    spec: PortSpec
    payload: jax.Array            # lines [L, N, *rest] or banked [G, N, N, *rest]
    rest_shape: Tuple[int, ...]
    words: int                    # prod(rest) — flattened word width


class BurstScheduler:
    """Batch queued read/write streams through one network call per flush."""

    def __init__(self, fabric: Fabric):
        self.fabric = fabric
        self.stats = SchedulerStats()
        self._reads: List[_Queued] = []
        self._writes: List[_Queued] = []

    # -- enqueue ---------------------------------------------------------------
    def _check_name(self, name: str) -> None:
        # flush() keys results by stream name; a duplicate (even read vs
        # write) would silently shadow one result
        if any(q.spec.name == name for q in self._reads + self._writes):
            raise ValueError(
                f"stream {name!r} already queued for this flush; give each "
                f"logical port a distinct name (e.g. 'kv_read'/'kv_write')")

    def enqueue_read(self, name: str, lines: jax.Array) -> PortSpec:
        """Queue a line stream ``[L, N, *rest]`` (L a multiple of N) for the
        read network.  Returns the :class:`PortSpec` keying the result."""
        n = self.fabric.n_ports
        if lines.ndim < 2 or lines.shape[1] != n or lines.shape[0] % n:
            raise ValueError(
                f"stream {name!r}: want [k*N, N, ...] lines for N={n}, "
                f"got {lines.shape}")
        self._check_name(name)
        spec = PortSpec(name=name, direction="read")
        rest = tuple(lines.shape[2:])
        self._reads.append(_Queued(spec, lines, rest, _prod(rest)))
        return spec

    def enqueue_write(self, name: str, banked: jax.Array) -> PortSpec:
        """Queue a banked buffer ``[G, N, N, *rest]`` for the write network."""
        n = self.fabric.n_ports
        if banked.ndim < 3 or banked.shape[1] != n or banked.shape[2] != n:
            raise ValueError(
                f"stream {name!r}: want [G, N, N, ...] banked for N={n}, "
                f"got {banked.shape}")
        self._check_name(name)
        spec = PortSpec(name=name, direction="write")
        rest = tuple(banked.shape[3:])
        self._writes.append(_Queued(spec, banked, rest, _prod(rest)))
        return spec

    # -- one scheduler step ----------------------------------------------------
    def flush(self) -> Dict[str, jax.Array]:
        """Run the queued traffic: one read-network call and one write-network
        call per dtype present, then scatter results back per stream name."""
        out: Dict[str, jax.Array] = {}
        out.update(self._flush_direction(self._reads, read=True))
        out.update(self._flush_direction(self._writes, read=False))
        self._reads, self._writes = [], []
        return out

    def _flush_direction(self, queue: List[_Queued],
                         read: bool) -> Dict[str, jax.Array]:
        n = self.fabric.n_ports
        out: Dict[str, jax.Array] = {}
        by_dtype: Dict[object, List[_Queued]] = {}
        for q in queue:
            by_dtype.setdefault(jnp.dtype(q.payload.dtype), []).append(q)
        for streams in by_dtype.values():
            self.stats.streams_served += len(streams)
            self.stats.network_calls += 1
            w_max = max(q.words for q in streams)
            flat = []
            for q in streams:
                lead = q.payload.shape[:2] if read else q.payload.shape[:3]
                x = q.payload.reshape(lead + (q.words,))
                if q.words < w_max:
                    pad = [(0, 0)] * (x.ndim - 1) + [(0, w_max - q.words)]
                    x = jnp.pad(x, pad)
                flat.append(x)
            burst = jnp.concatenate(flat, axis=0)
            moved = self.fabric.read(burst) if read else self.fabric.write(burst)
            # split back: stream i covers groups [off, off + L_i/N) (read) or
            # lines [off, off + G_i*N) (write)
            off = 0
            for q in streams:
                count = (q.payload.shape[0] // n if read
                         else q.payload.shape[0] * n)
                piece = moved[off:off + count]
                off += count
                piece = piece[..., :q.words]
                out[q.spec.name] = piece.reshape(piece.shape[:-1] + q.rest_shape)
        return out


def _prod(shape: Tuple[int, ...]) -> int:
    p = 1
    for s in shape:
        p *= s
    return p
