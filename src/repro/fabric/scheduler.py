"""Burst scheduler: many logical streams, one network invocation per step.

The paper's burst machinery (§III-C: MaxBurstLen-deep banks, per-port
head/tail pointers, interference-free progress — modelled cycle-by-cycle in
:mod:`repro.core.burst`) exists so that *independent* traffic shares one
physical transposition network.  This module is the framework-level
generalisation: consumers (KV read, KV write, weight stream, MoE expert
dispatch) declare logical streams against a shared :class:`Fabric`; at each
step the scheduler merges every queued stream into one burst per direction
and dtype, runs the read (resp. write) network once per burst, and hands
each consumer its slice back.

Packing (``pack="packed"``, the default)
----------------------------------------
The words of a line move independently through the network (the transpose
acts on the (line, word-index) axes; the word payload rides along), so a
stream of ``k*N`` lines with ``W`` payload elements per word is *exactly*
the same traffic as ``N`` lines with ``k*W`` payload elements — the line
groups fold into the word axis.  Streams sharing a dtype therefore
normalise to ``[N, N, k_i*W_i]`` tiles and concatenate along the word axis
into one ``[N, N, W_total]`` burst: the network moves **zero padding**, and
each stream's ``(offset, words)`` extent within the burst is recorded on
its :class:`PortSpec` — the framework form of the paper's per-port
head/tail pointers into the shared deep-narrow banks.  ``pack="pad"`` keeps
the old pad-to-widest line-axis concatenation for A/B benchmarking.
Streams of different dtypes cannot share a burst bit-identically, so the
scheduler keeps one burst per dtype and direction either way.

Issue/commit pipeline (§III-C double buffer)
--------------------------------------------
``flush()`` is split into :meth:`issue` (dispatch the queued bursts through
the network) and :meth:`commit` (adopt the results).  The pipeline is one
deep: after ``issue()`` the *next* burst's streams may be enqueued while
the consumer computes on the previous ``commit()``'s results — under JAX's
async dispatch (and inside ``jit``, under XLA's scheduler) the issued
transfer genuinely overlaps consumer compute, which is the paper's
input/output double buffer expressed once for every consumer.  ``flush()``
remains as ``issue(); commit()`` for synchronous callers.

``stats`` distinguishes ``flushes`` (issue/commit cycles) from
``network_calls`` (one per direction and dtype present in a burst) and
counts moved vs padded word-axis elements, which is exactly the contrast
``benchmarks/fabric_unified.py`` measures against per-consumer
:class:`Fabric` calls.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import PortSpec
from repro.fabric.fabric import Fabric


@dataclasses.dataclass
class SchedulerStats:
    """Traffic accounting for a :class:`BurstScheduler`.

    ``flushes`` counts issue/commit cycles (a ``flush()`` is one);
    ``network_calls`` counts actual read-/write-network invocations — one
    per (direction, dtype) group present in a burst, so a flush carrying
    bf16 reads, f32 reads and bf16 writes is 1 flush but 3 network calls.
    ``words_moved``/``words_padded`` count word-axis elements carried by the
    network: moved is the payload consumers asked for, padded is the zero
    fill the ``pack="pad"`` layout adds (always 0 under ``pack="packed"``).
    """
    streams_served: int = 0
    flushes: int = 0
    network_calls: int = 0
    words_moved: int = 0
    words_padded: int = 0

    @property
    def calls_saved(self) -> int:
        return self.streams_served - self.network_calls


@dataclasses.dataclass
class _Queued:
    spec: PortSpec
    payload: jax.Array            # lines [L, N, *rest] or banked [G, N, N, *rest]
    rest_shape: Tuple[int, ...]
    width: int                    # prod(rest) — payload elements per word


class BurstScheduler:
    """Batch queued read/write streams through one network call per burst.

    ``pack`` defaults to the fabric's :attr:`FabricConfig.pack`; pass an
    external :class:`SchedulerStats` to accumulate traffic accounting across
    scheduler instances (e.g. one instance per traced decode step).
    """

    def __init__(self, fabric: Fabric, pack: Optional[str] = None,
                 stats: Optional[SchedulerStats] = None):
        self.fabric = fabric
        self.pack = pack or fabric.config.pack
        if self.pack not in ("packed", "pad"):
            raise ValueError(f"unknown burst packing {self.pack!r}")
        self.stats = stats if stats is not None else SchedulerStats()
        self._reads: List[_Queued] = []
        self._writes: List[_Queued] = []
        self._inflight: Optional[Dict[str, jax.Array]] = None

    # -- enqueue ---------------------------------------------------------------
    def _check_name(self, name: str) -> None:
        # commit() keys results by stream name; a duplicate (even read vs
        # write) would silently shadow one result
        if any(q.spec.name == name for q in self._reads + self._writes):
            raise ValueError(
                f"stream {name!r} already queued for this burst; give each "
                f"logical port a distinct name (e.g. 'kv_read'/'kv_write')")

    def _extent(self, queue: List[_Queued], dtype) -> int:
        """Word-axis offset of the next stream within its dtype group."""
        return sum(q.spec.words for q in queue
                   if jnp.dtype(q.payload.dtype) == dtype)

    def enqueue_read(self, name: str, lines: jax.Array) -> PortSpec:
        """Queue a line stream ``[L, N, *rest]`` (L a multiple of N) for the
        read network.  Returns the :class:`PortSpec` keying the result, with
        the stream's packed-burst ``(offset, words)`` extent filled in."""
        n = self.fabric.n_ports
        if lines.ndim < 2 or lines.shape[1] != n or lines.shape[0] % n:
            raise ValueError(
                f"stream {name!r}: want [k*N, N, ...] lines for N={n}, "
                f"got {lines.shape}")
        self._check_name(name)
        rest = tuple(lines.shape[2:])
        words = (lines.shape[0] // n) * _prod(rest)
        spec = PortSpec(
            name=name, direction="read", words=words,
            offset=self._extent(self._reads, jnp.dtype(lines.dtype)))
        self._reads.append(_Queued(spec, lines, rest, _prod(rest)))
        return spec

    def enqueue_write(self, name: str, banked: jax.Array) -> PortSpec:
        """Queue a banked buffer ``[G, N, N, *rest]`` for the write network."""
        n = self.fabric.n_ports
        if banked.ndim < 3 or banked.shape[1] != n or banked.shape[2] != n:
            raise ValueError(
                f"stream {name!r}: want [G, N, N, ...] banked for N={n}, "
                f"got {banked.shape}")
        self._check_name(name)
        rest = tuple(banked.shape[3:])
        words = banked.shape[0] * _prod(rest)
        spec = PortSpec(
            name=name, direction="write", words=words,
            offset=self._extent(self._writes, jnp.dtype(banked.dtype)))
        self._writes.append(_Queued(spec, banked, rest, _prod(rest)))
        return spec

    # -- the issue/commit pipeline ---------------------------------------------
    def issue(self) -> None:
        """Dispatch the queued traffic through the networks (one read and one
        write invocation per dtype present) and clear the queues, so the next
        burst's streams can be enqueued while this one is in flight.  The
        pipeline is one deep: a second :meth:`issue` before :meth:`commit`
        is an ordering error."""
        if self._inflight is not None:
            raise RuntimeError(
                "issue() with a burst already in flight; commit() the "
                "previous burst first (the pipeline is one deep)")
        out: Dict[str, jax.Array] = {}
        out.update(self._run_direction(self._reads, read=True))
        out.update(self._run_direction(self._writes, read=False))
        self._reads, self._writes = [], []
        self._inflight = out
        self.stats.flushes += 1

    def commit(self) -> Dict[str, jax.Array]:
        """Adopt the in-flight burst's results, keyed by stream name."""
        if self._inflight is None:
            raise RuntimeError("commit() without a matching issue()")
        out, self._inflight = self._inflight, None
        return out

    def flush(self) -> Dict[str, jax.Array]:
        """Synchronous form: ``issue()`` immediately followed by ``commit()``."""
        self.issue()
        return self.commit()

    # -- burst construction ----------------------------------------------------
    def _run_direction(self, queue: List[_Queued],
                       read: bool) -> Dict[str, jax.Array]:
        out: Dict[str, jax.Array] = {}
        by_dtype: Dict[object, List[_Queued]] = {}
        for q in queue:
            by_dtype.setdefault(jnp.dtype(q.payload.dtype), []).append(q)
        for streams in by_dtype.values():
            self.stats.streams_served += len(streams)
            self.stats.network_calls += 1
            if self.pack == "packed":
                out.update(self._run_packed(streams, read))
            else:
                out.update(self._run_padded(streams, read))
        return out

    def _run_packed(self, streams: List[_Queued],
                    read: bool) -> Dict[str, jax.Array]:
        """Word-axis packing: fold each stream's group axis into the word
        axis (``[k*N, N, W] ≡ [N, N, k*W]`` — words of a line move
        independently), concatenate along words, run the network once on the
        ``[N, N, W_total]`` tile, and slice each stream's extent back.

        Payloads travel as machine words: the networks are pure word
        movement (rolls/selects/gathers, no arithmetic), so each stream is
        bitcast to the same-width unsigned integer for the transfer and back
        on arrival — bit-exact by construction, and it keeps the burst off
        XLA:CPU's slow-path bf16 concatenate/select kernels (the packing
        wall-clock win depends on it)."""
        n = self.fabric.n_ports
        tiles = []
        for q in streams:
            groups = (q.payload.shape[0] // n if read else q.payload.shape[0])
            flat = _int_view(q.payload.reshape((groups, n, n, q.width)))
            tiles.append(flat.transpose(1, 2, 0, 3).reshape(n, n, -1))
            self.stats.words_moved += groups * n * n * q.width
        burst = tiles[0] if len(tiles) == 1 else jnp.concatenate(tiles, axis=-1)
        moved = (self.fabric.read(burst)[0] if read
                 else self.fabric.write(burst[None]))
        out: Dict[str, jax.Array] = {}
        for q in streams:
            piece = moved[:, :, q.spec.offset:q.spec.offset + q.spec.words]
            groups = q.spec.words // q.width
            piece = piece.reshape(n, n, groups, q.width).transpose(2, 0, 1, 3)
            piece = _un_view(piece, q.payload.dtype)
            lead = (groups, n, n) if read else (groups * n, n)
            out[q.spec.name] = piece.reshape(lead + q.rest_shape)
        return out

    def _run_padded(self, streams: List[_Queued],
                    read: bool) -> Dict[str, jax.Array]:
        """Pad-to-widest fallback (``pack="pad"``): streams concatenate along
        the line axis after zero-padding narrower words to the widest — the
        network moves the padding, which is what packed mode eliminates."""
        n = self.fabric.n_ports
        out: Dict[str, jax.Array] = {}
        w_max = max(q.width for q in streams)
        flat = []
        for q in streams:
            lead = q.payload.shape[:2] if read else q.payload.shape[:3]
            x = q.payload.reshape(lead + (q.width,))
            lines = q.payload.shape[0] * (1 if read else n)
            self.stats.words_moved += lines * n * q.width
            self.stats.words_padded += lines * n * (w_max - q.width)
            if q.width < w_max:
                pad = [(0, 0)] * (x.ndim - 1) + [(0, w_max - q.width)]
                x = jnp.pad(x, pad)
            flat.append(x)
        burst = jnp.concatenate(flat, axis=0)
        moved = self.fabric.read(burst) if read else self.fabric.write(burst)
        # split back: stream i covers groups [off, off + L_i/N) (read) or
        # lines [off, off + G_i*N) (write)
        off = 0
        for q in streams:
            count = (q.payload.shape[0] // n if read
                     else q.payload.shape[0] * n)
            piece = moved[off:off + count]
            off += count
            piece = piece[..., :q.width]
            out[q.spec.name] = piece.reshape(piece.shape[:-1] + q.rest_shape)
        return out


_WORD_VIEW = {1: jnp.uint8, 2: jnp.uint16, 4: jnp.uint32}


def _int_view(x: jax.Array) -> jax.Array:
    """Same-width unsigned-integer view of a payload (identity for ints and
    for widths without a same-size unsigned view)."""
    if (jnp.issubdtype(x.dtype, jnp.integer)
            or jnp.issubdtype(x.dtype, jnp.bool_)
            or jnp.dtype(x.dtype).itemsize not in _WORD_VIEW):
        return x
    return jax.lax.bitcast_convert_type(
        x, _WORD_VIEW[jnp.dtype(x.dtype).itemsize])


def _un_view(x: jax.Array, dtype) -> jax.Array:
    """Undo :func:`_int_view` on arrival."""
    return x if x.dtype == jnp.dtype(dtype) else (
        jax.lax.bitcast_convert_type(x, dtype))


def _prod(shape: Tuple[int, ...]) -> int:
    p = 1
    for s in shape:
        p *= s
    return p
