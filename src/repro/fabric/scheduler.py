"""Burst scheduler: many logical streams, one network invocation per step.

The paper's burst machinery (§III-C: MaxBurstLen-deep banks, per-port
head/tail pointers, interference-free progress — modelled cycle-by-cycle in
:mod:`repro.core.burst`) exists so that *independent* traffic shares one
physical transposition network.  This module is the framework-level
generalisation: consumers (KV read, KV write, weight stream, MoE expert
dispatch) declare logical streams against a shared :class:`Fabric`; at each
step the scheduler merges every queued stream into one burst per direction
and dtype, runs the read (resp. write) network once per burst, and hands
each consumer its slice back.

Packing (``pack="packed"``, the default)
----------------------------------------
The words of a line move independently through the network (the transpose
acts on the (line, word-index) axes; the word payload rides along), so a
stream of ``k*N`` lines with ``W`` payload elements per word is *exactly*
the same traffic as ``N`` lines with ``k*W`` payload elements — the line
groups fold into the word axis.  Streams sharing a dtype therefore
normalise to ``[N, N, k_i*W_i]`` tiles and concatenate along the word axis
into one ``[N, N, W_total]`` burst: the network moves **zero padding**, and
each stream's ``(offset, words)`` extent within the burst is recorded on
its :class:`PortSpec` — the framework form of the paper's per-port
head/tail pointers into the shared deep-narrow banks.  ``pack="pad"`` keeps
the old pad-to-widest line-axis concatenation for A/B benchmarking.
Streams of different dtypes cannot share a burst bit-identically, so the
scheduler keeps one burst per dtype and direction either way.

Machine-word lane folding (``word_fold``)
-----------------------------------------
Payloads travel as machine words (same-width unsigned-integer views), and on
packed bursts adjacent narrow words additionally *fold* into wider machine
words before the network runs: bf16/u16 pairs ride u32 lanes, and under x64
pairs/quads ride u64 — halving/quartering the lane count every exchange
stage touches, for the same total bits.  This is the framework form of the
paper's premise that the unit moves whole ``W_line``-bit lines per cycle
(§III): the network never cares what a "word" is, so the scheduler picks the
widest machine word the dtype and stream geometry allow.  The fold factor is
per dtype group — the largest ``f ≤ word_fold`` (``"auto"`` = 4) every
member stream supports, where a stream supports ``f`` when ``f`` divides its
per-group word count (fold adjacent words of a line group, applied as part
of the packing bitcast) or its group count (fold corresponding words of
adjacent groups; the word-axis order inside a stream's extent is a scheduler
internal).  Odd word counts therefore degrade the group to a narrower fold,
never to an error, and the unfold on arrival is an exact bitcast — parity is
guaranteed because the networks are pure word movement.  ``pack="pad"``
folds too — on its padded word axis (the factor must divide the padded
width ``w_max``), so the pack A/B isolates packing from lane width.  At
``word_fold=1`` the pad layout is byte-for-byte the PR 1 baseline (raw
payload dtype, no integer view).  On XLA:CPU the fold is
roughly wall-clock-neutral (the widening view costs what the lane savings
recoup); it exists to model TPU lane packing, where a u32/u64 lane is the
unit the VPU actually moves — and it halves/quarters the elements every
select the exchange network emits touches.

Issue/commit pipeline (§III-C double buffer)
--------------------------------------------
``flush()`` is split into :meth:`issue` (dispatch the queued bursts through
the network) and :meth:`commit` (adopt the results).  The pipeline is one
deep: after ``issue()`` the *next* burst's streams may be enqueued while
the consumer computes on the previous ``commit()``'s results — under JAX's
async dispatch (and inside ``jit``, under XLA's scheduler) the issued
transfer genuinely overlaps consumer compute, which is the paper's
input/output double buffer expressed once for every consumer.  ``flush()``
remains as ``issue(); commit()`` for synchronous callers.

Sparse-extent streams (the fused page-table gather)
---------------------------------------------------
A paged KV pool's consumer only needs the frames its page table maps, so a
stream may be enqueued with an explicit frame-index operand:
``enqueue_read(..., gather=idx)`` names the live lines of a larger backing
stream (sentinel entries — indices past the backing extent — read as zero
frames), and ``enqueue_write(..., scatter=idx, into=pool_lines)`` lands the
moved lines back at their indexed pool rows (sentinels drop, untouched rows
never move).  The burst then carries ``len(idx)`` frames instead of the
pool's — decode traffic scales with live tokens, not pool capacity.  On the
unrolled path the gather lowers as a take feeding the shared packed burst
(still one network call per dtype, the indexed lines packed next to the
dense streams); on the kernelized medusa fabric each sparse stream lowers
through the fused gather/scatter burst kernel with the indices as a
scalar-prefetched operand (one launch per stream — indirection + exchange
fused, no materialized full-pool intermediate).  Both lowerings are
bit-identical to the gather-after-burst form by construction (the networks
are pure word movement, and take commutes with them).

``stats`` distinguishes ``flushes`` (issue/commit cycles) from
``network_calls`` (one per direction and dtype present in a burst) and
counts moved vs padded word-axis elements, which is exactly the contrast
``benchmarks/fabric_unified.py`` measures against per-consumer
:class:`Fabric` calls.  ``words_live``/``gather_fused_bursts`` single out
the sparse-extent traffic (see :class:`SchedulerStats`).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import PortSpec
from repro.fabric.fabric import Fabric


@dataclasses.dataclass
class SchedulerStats:
    """Traffic accounting for a :class:`BurstScheduler`.

    ``flushes`` counts issue/commit cycles (a ``flush()`` is one);
    ``network_calls`` counts actual read-/write-network invocations — one
    per (direction, dtype) group present in a burst, so a flush carrying
    bf16 reads, f32 reads and bf16 writes is 1 flush but 3 network calls.
    ``words_moved``/``words_padded`` count word-axis elements carried by the
    network: moved is the payload consumers asked for, padded is the zero
    fill the ``pack="pad"`` layout adds (always 0 under ``pack="packed"``).
    ``words_folded`` counts the word-axis elements machine-word folding
    removed from the network's lane view (they ride inside wider machine
    words instead — a fold of 2 folds away half of a burst's elements), so
    ``words_moved - words_folded`` is the post-fold lane traffic the network
    actually touches (for the pad layout the fold rides the padded width,
    so folded counts include padding riding wider lanes).  ``kernel_bursts``
    counts the network calls that lowered through the fused single-kernel
    burst path (:meth:`repro.fabric.Fabric.read_burst` with kernels
    enabled).  ``prefill_bursts`` counts admission waves the serving engine
    installed through one shared write burst (``prefill/*`` streams — see
    :meth:`repro.fabric.PagedKVCache.admit_wave`) instead of per-layer
    splices.

    ``words_live`` counts the word-axis elements carried for sparse-extent
    (gather/scatter-indexed) streams — the fused page-table contract's
    traffic, which scales with live frames; a fused decode step shows
    ``words_live > 0`` where the gather-after-burst fallback moves the
    whole pool as ordinary ``words_moved`` with ``words_live == 0``.
    ``gather_fused_bursts`` counts the network calls that carried at least
    one sparse-extent stream (on the kernelized path, the fused
    gather/scatter launches themselves) — the printed census can now tell
    fused from fallback decode.

    ``words_cross_shard``/``collective_calls`` single out the pool-sharded
    lowering (``FabricConfig.pool_shards > 1``): each sharded sparse burst
    is one ``collective_call`` (the exchange hop between the per-shard
    fused gathers), and ``words_cross_shard`` counts the word-axis elements
    of the exchange buffer's off-diagonal blocks — the words that
    physically leave their owning shard, including bucket padding (the
    collective moves whole padded buckets; the diagonal block stays local).
    ``words_cross_shard < words_moved`` is the locality win the sharded
    bench cells assert: with round-robin page striping roughly ``(S-1)/S``
    of the live traffic crosses, never all of it.

    The graceful-degradation counters cover the serving engine's
    oversubscription path: ``preemptions`` counts victim slots evicted so a
    higher-priority request could run; ``swap_bursts``/``swap_out_words``/
    ``swap_in_words`` count the ``swap/*`` sparse-extent streams that stage
    a victim's live frames to host memory over the read network and restore
    them over the write network (swap traffic is burst traffic — counted,
    packed and bit-exact like every other stream); ``bursts_retried``
    counts swap transfers re-run after an end-to-end parity-word mismatch
    (injected corruption); ``faults_recovered`` counts engine steps that
    rolled back to the last consistent state and replayed after an
    injected mid-step failure.

    The admission-control counters extend the graceful-degradation census
    into the scheduling layer above the fabric: ``requests_shed`` counts
    requests rejected at admission instead of missing silently —
    ``shed_queue_full`` of them bounced off the bounded submit queue
    (backpressure), ``shed_deadline`` were load-shed because their SLO
    deadline was provably unmeetable given pool headroom and queue depth.
    ``slo_missed_served`` / ``slo_missed_shed`` split the deadline-miss
    census by exit path: a deadlined request that retires late counts
    *served*, one that exits any other way (shed at submit, shed from the
    queue once provably unmeetable, rejected as never-servable) counts
    *shed* — every deadlined request is counted at exactly one exit, so the
    two sum to the true miss count (the old ``slo_misses`` counted only
    late retirements).  ``aging_promotions`` counts admissions where
    anti-starvation aging had boosted the candidate's effective priority
    above its raw class (queued wait divided by the engine's ``aging``
    quantum) — the census evidence that the fairness mechanism, not raw
    rank, got the request in.

    ``tokens_dropped`` counts token→expert assignments the MoE capacity
    dispatch dropped (rank past the static per-expert capacity — their
    scatter indices became sentinels and the residual passed through).
    Unlike the trace-time word counters it is runtime-exact: drop counts
    are data-dependent, so a traced ``moe_apply`` accumulates them through
    a debug callback that fires once per executed dispatch (per layer, per
    step), never once per trace.  Before this counter a dropped token was
    indistinguishable from a routed one in every census.
    """
    streams_served: int = 0
    flushes: int = 0
    network_calls: int = 0
    words_moved: int = 0
    words_padded: int = 0
    words_folded: int = 0
    words_live: int = 0
    words_cross_shard: int = 0
    kernel_bursts: int = 0
    gather_fused_bursts: int = 0
    prefill_bursts: int = 0
    collective_calls: int = 0
    preemptions: int = 0
    swap_bursts: int = 0
    swap_out_words: int = 0
    swap_in_words: int = 0
    bursts_retried: int = 0
    faults_recovered: int = 0
    requests_shed: int = 0
    shed_queue_full: int = 0
    shed_deadline: int = 0
    slo_missed_served: int = 0
    slo_missed_shed: int = 0
    aging_promotions: int = 0
    tokens_dropped: int = 0

    @property
    def calls_saved(self) -> int:
        return self.streams_served - self.network_calls


@dataclasses.dataclass
class _Queued:
    spec: PortSpec
    payload: jax.Array            # lines [L, N, *rest] or banked [G, N, N, *rest]
    rest_shape: Tuple[int, ...]
    width: int                    # prod(rest) — payload elements per word
    groups: int                   # line groups (L // N, resp. G)
    # sparse extent (fused page-table gather): reads carry `gather` frame
    # indices into the payload's line axis; writes carry `scatter` target
    # rows plus the pool stream `into` they land in
    gather: Optional[jax.Array] = None
    scatter: Optional[jax.Array] = None
    into: Optional[jax.Array] = None
    # pool-sharded sparse extent: `(fetch, place, k_tot)` from
    # ``repro.fabric.sharded.shard_plan`` — the stream lowers as per-shard
    # fused gathers bridged by one collective instead of a single-device
    # gather (reads: payload is the sharded pool stream [R, F, N, *rest];
    # writes: payload is banked and `into` is the sharded pool stream)
    shard: Optional[Tuple] = None

    @property
    def sparse(self) -> bool:
        return (self.gather is not None or self.scatter is not None
                or self.shard is not None)


class BurstScheduler:
    """Batch queued read/write streams through one network call per burst.

    ``pack`` defaults to the fabric's :attr:`FabricConfig.pack` and
    ``word_fold`` to its :attr:`FabricConfig.word_fold`; pass an external
    :class:`SchedulerStats` to accumulate traffic accounting across
    scheduler instances (e.g. one instance per traced decode step).
    """

    def __init__(self, fabric: Fabric, pack: Optional[str] = None,
                 word_fold=None, stats: Optional[SchedulerStats] = None):
        self.fabric = fabric
        self.pack = pack or fabric.config.pack
        if self.pack not in ("packed", "pad"):
            raise ValueError(f"unknown burst packing {self.pack!r}")
        self.word_fold = (fabric.config.word_fold if word_fold is None
                          else word_fold)
        if self.word_fold not in ("auto", 1, 2, 4):
            raise ValueError(f"word_fold must be 'auto', 1, 2 or 4, "
                             f"got {self.word_fold!r}")
        self.stats = stats if stats is not None else SchedulerStats()
        self._reads: List[_Queued] = []
        self._writes: List[_Queued] = []
        self._inflight: Optional[Dict[str, jax.Array]] = None

    # -- enqueue ---------------------------------------------------------------
    def _check_name(self, name: str) -> None:
        # commit() keys results by stream name; a duplicate (even read vs
        # write) would silently shadow one result
        if any(q.spec.name == name for q in self._reads + self._writes):
            raise ValueError(
                f"stream {name!r} already queued for this burst; give each "
                f"logical port a distinct name (e.g. 'kv_read'/'kv_write')")

    def _extent(self, queue: List[_Queued], dtype) -> int:
        """Word-axis offset of the next stream within its dtype group."""
        return sum(q.spec.words for q in queue
                   if jnp.dtype(q.payload.dtype) == dtype)

    def enqueue_read(self, name: str, lines: jax.Array,
                     gather: Optional[jax.Array] = None,
                     shard: Optional[Tuple] = None) -> PortSpec:
        """Queue a line stream ``[L, N, *rest]`` (L a multiple of N) for the
        read network.  Returns the :class:`PortSpec` keying the result, with
        the stream's packed-burst ``(offset, words)`` extent filled in.

        ``gather`` makes the stream sparse-extent (the fused page-table
        gather): ``lines`` is the full backing pool and ``gather [K]``
        (K a multiple of N; entries ``>= L`` are sentinels reading as zero
        frames) names the live lines — the burst carries only those, and the
        result is the banked ``[K//N, N, N, *rest]`` of the addressed
        frames.  The spec's ``words`` is the live extent; ``pool_words``
        records what the gather-after-burst fallback would have moved.

        ``shard = (fetch, place, k_tot)`` (from
        :func:`repro.fabric.sharded.shard_plan`) is the pool-sharded form of
        ``gather``: ``lines`` is the rep-major pool stream ``[R, F, N,
        *rest]`` with its frame axis sharded over the ``pool`` mesh axis,
        and the stream lowers as per-shard fused gathers bridged by one
        collective — same banked ``[k_tot//N, N, N, *rest]`` result, bit
        for bit."""
        n = self.fabric.n_ports
        self._check_name(name)
        if shard is not None:
            if gather is not None:
                raise ValueError(f"stream {name!r}: shard= and gather= are "
                                 f"mutually exclusive lowerings")
            if lines.ndim < 3 or lines.shape[2] != n:
                raise ValueError(
                    f"stream {name!r}: sharded read wants the rep-major pool "
                    f"stream [R, F, N, ...] for N={n}, got {lines.shape}")
            fetch, place, k_tot = shard
            s = fetch.shape[0]
            if k_tot % (s * n):
                raise ValueError(
                    f"stream {name!r}: k_tot={k_tot} must split into {s} "
                    f"shard blocks of whole N={n} groups")
            rest = tuple(lines.shape[3:])
            width = _prod(rest)
            groups = k_tot // n
            spec = PortSpec(
                name=name, direction="read", words=groups * width,
                offset=self._extent(self._reads, jnp.dtype(lines.dtype)),
                gathered=True,
                pool_words=lines.shape[0] * lines.shape[1] * width // n)
            self._reads.append(_Queued(spec, lines, rest, width, groups,
                                       shard=shard))
            return spec
        if lines.ndim < 2 or lines.shape[1] != n or lines.shape[0] % n:
            raise ValueError(
                f"stream {name!r}: want [k*N, N, ...] lines for N={n}, "
                f"got {lines.shape}")
        rest = tuple(lines.shape[2:])
        width = _prod(rest)
        if gather is not None:
            if gather.ndim != 1 or gather.shape[0] % n:
                raise ValueError(
                    f"stream {name!r}: gather indices must be [k*N] for "
                    f"N={n}, got {gather.shape}")
            groups = gather.shape[0] // n
        else:
            groups = lines.shape[0] // n
        words = groups * width
        spec = PortSpec(
            name=name, direction="read", words=words,
            offset=self._extent(self._reads, jnp.dtype(lines.dtype)),
            gathered=gather is not None,
            pool_words=(lines.shape[0] // n) * width if gather is not None
            else 0)
        self._reads.append(_Queued(spec, lines, rest, width, groups,
                                   gather=gather))
        return spec

    def enqueue_write(self, name: str, banked: jax.Array,
                      scatter: Optional[jax.Array] = None,
                      into: Optional[jax.Array] = None,
                      shard: Optional[Tuple] = None) -> PortSpec:
        """Queue a banked buffer ``[G, N, N, *rest]`` for the write network.

        ``scatter``/``into`` make the stream sparse-extent: the write
        network reassembles the banked frames' lines and each lands at its
        indexed row of the pool stream ``into [L, N, *rest]`` (sentinel
        indices ``>= L`` drop — padding rows are free; rows the indices
        never touch keep their frames without moving).  The committed
        result is the updated pool stream.

        ``shard = (fetch, place, k_tot)`` is the pool-sharded form of
        ``scatter``: ``into`` is the rep-major pool stream ``[R, F, N,
        *rest]`` sharded over the ``pool`` mesh axis, and each banked frame
        reaches its owning shard through one collective before the local
        fused scatter lands it."""
        n = self.fabric.n_ports
        if banked.ndim < 3 or banked.shape[1] != n or banked.shape[2] != n:
            raise ValueError(
                f"stream {name!r}: want [G, N, N, ...] banked for N={n}, "
                f"got {banked.shape}")
        self._check_name(name)
        if shard is not None:
            if scatter is not None:
                raise ValueError(f"stream {name!r}: shard= and scatter= are "
                                 f"mutually exclusive lowerings")
            if into is None:
                raise ValueError(f"stream {name!r}: sharded write needs the "
                                 f"pool stream to land in (into=)")
            if into.ndim != banked.ndim or into.shape[2] != n \
                    or into.shape[3:] != banked.shape[3:]:
                raise ValueError(
                    f"stream {name!r}: sharded scatter target {into.shape} "
                    f"does not match banked frames {banked.shape} "
                    f"(want rep-major [R, F, N, ...])")
            fetch, _, k_tot = shard
            if k_tot != banked.shape[0] * n:
                raise ValueError(
                    f"stream {name!r}: plan k_tot={k_tot} != banked line "
                    f"count {banked.shape[0] * n}")
            rest = tuple(banked.shape[3:])
            width = _prod(rest)
            spec = PortSpec(
                name=name, direction="write", words=banked.shape[0] * width,
                offset=self._extent(self._writes, jnp.dtype(banked.dtype)),
                gathered=True,
                pool_words=into.shape[0] * into.shape[1] * width // n)
            self._writes.append(_Queued(spec, banked, rest, width,
                                        banked.shape[0], into=into,
                                        shard=shard))
            return spec
        if (scatter is None) != (into is None):
            raise ValueError(
                f"stream {name!r}: sparse writes need both scatter indices "
                f"and the pool stream to land in (into=)")
        rest = tuple(banked.shape[3:])
        width = _prod(rest)
        if scatter is not None:
            if scatter.ndim != 1 or scatter.shape[0] != banked.shape[0] * n:
                raise ValueError(
                    f"stream {name!r}: scatter indices {scatter.shape} must "
                    f"match the banked line count {banked.shape[0] * n}")
            if into.shape[1:] != banked.shape[2:] or into.ndim != banked.ndim - 1:
                raise ValueError(
                    f"stream {name!r}: scatter target {into.shape} does not "
                    f"match banked lines {banked.shape}")
        words = banked.shape[0] * width
        spec = PortSpec(
            name=name, direction="write", words=words,
            offset=self._extent(self._writes, jnp.dtype(banked.dtype)),
            gathered=scatter is not None,
            pool_words=(into.shape[0] // n) * width if scatter is not None
            else 0)
        self._writes.append(_Queued(spec, banked, rest, width,
                                    banked.shape[0], scatter=scatter,
                                    into=into))
        return spec

    # -- the issue/commit pipeline ---------------------------------------------
    def issue(self) -> None:
        """Dispatch the queued traffic through the networks (one read and one
        write invocation per dtype present) and clear the queues, so the next
        burst's streams can be enqueued while this one is in flight.  The
        pipeline is one deep: a second :meth:`issue` before :meth:`commit`
        is an ordering error."""
        if self._inflight is not None:
            raise RuntimeError(
                "issue() with a burst already in flight; commit() the "
                "previous burst first (the pipeline is one deep)")
        out: Dict[str, jax.Array] = {}
        out.update(self._run_direction(self._reads, read=True))
        out.update(self._run_direction(self._writes, read=False))
        self._reads, self._writes = [], []
        self._inflight = out
        self.stats.flushes += 1

    def commit(self) -> Dict[str, jax.Array]:
        """Adopt the in-flight burst's results, keyed by stream name."""
        if self._inflight is None:
            raise RuntimeError("commit() without a matching issue()")
        out, self._inflight = self._inflight, None
        return out

    def flush(self) -> Dict[str, jax.Array]:
        """Synchronous form: ``issue()`` immediately followed by ``commit()``."""
        self.issue()
        return self.commit()

    # -- burst construction ----------------------------------------------------
    def _run_direction(self, queue: List[_Queued],
                       read: bool) -> Dict[str, jax.Array]:
        out: Dict[str, jax.Array] = {}
        n = self.fabric.n_ports
        by_dtype: Dict[object, List[_Queued]] = {}
        for q in queue:
            by_dtype.setdefault(jnp.dtype(q.payload.dtype), []).append(q)
        for dtype, streams in by_dtype.items():
            self.stats.streams_served += len(streams)
            sparse = [q for q in streams if q.sparse]
            for q in sparse:
                self.stats.words_live += q.groups * n * n * q.width
            sharded = [q for q in streams if q.shard is not None]
            if sharded:
                # pool-sharded lowering: each stream is its own two-hop
                # collective burst (per-shard fused gathers + one exchange);
                # dense streams of the dtype still share one packed burst
                for q in sharded:
                    out[q.spec.name] = self._run_sparse_sharded(q, read)
                streams = [q for q in streams if q.shard is None]
                sparse = [q for q in streams if q.sparse]
                if not streams:
                    continue
            if sparse and self.fabric.burst_kernelized_for(dtype):
                # fused lowering: each sparse stream is one gather/scatter
                # burst kernel launch (indices ride as a prefetched operand
                # — indirection + exchange in one kernel); dense streams of
                # the dtype still share one packed burst
                for q in sparse:
                    out[q.spec.name] = self._run_sparse_kernel(q, read)
                streams = [q for q in streams if not q.sparse]
                if not streams:
                    continue
            elif sparse:
                # unrolled lowering: gathers become takes feeding the shared
                # burst (the network still runs once per dtype, on live
                # frames only); scatters land after the network returns
                self.stats.gather_fused_bursts += 1
                streams = [self._materialize_gather(q) for q in streams]
            self.stats.network_calls += 1
            if self.pack == "packed":
                res = self._run_packed(streams, read)
            else:
                res = self._run_padded(streams, read)
            for q in streams:
                if q.scatter is not None:
                    res[q.spec.name] = q.into.at[q.scatter].set(
                        res[q.spec.name], mode="drop")
            out.update(res)
        return out

    def _materialize_gather(self, q: _Queued) -> _Queued:
        """Unrolled-path form of a sparse read: the frame gather lowers as a
        take (sentinels fill zero frames) whose result joins the shared
        burst like any dense stream.  Non-gather streams pass through."""
        if q.gather is None:
            return q
        taken = jnp.take(q.payload, q.gather, axis=0, mode="fill",
                         fill_value=0)
        return dataclasses.replace(q, payload=taken, gather=None)

    def _sparse_fold(self, q: _Queued) -> int:
        """Fold factor for one sparse-extent stream on the kernel path:
        within-line only (the index operand addresses whole frames, so the
        fold must divide the frame's word count)."""
        return self._fold_factor(q.payload.dtype, lambda f: q.width % f == 0)

    def _run_sparse_kernel(self, q: _Queued, read: bool) -> jax.Array:
        """One sparse-extent stream through the fused gather/scatter burst
        kernel: the pool stream (and, for writes, the scatter target) is
        viewed as machine words, the indices ride the launch prefetched,
        and only the live frames move."""
        n = self.fabric.n_ports
        fold = self._sparse_fold(q)
        elems = q.groups * n * n * q.width
        self.stats.network_calls += 1
        self.stats.kernel_bursts += 1
        self.stats.gather_fused_bursts += 1
        self.stats.words_moved += elems
        self.stats.words_folded += elems - elems // fold
        wide = (machine_word_dtype(
            jnp.dtype(q.payload.dtype).itemsize * fold) if fold > 1 else None)

        def view(x, lead_ndim):
            flat = x.reshape(x.shape[:lead_ndim] + (q.width,))
            if fold == 1:
                return _int_view(flat)
            return jax.lax.bitcast_convert_type(
                flat.reshape(flat.shape[:-1] + (q.width // fold, fold)), wide)

        if read:
            lines = view(q.payload, 2)                     # [L, N, w/f]
            banked = self.fabric.read_burst(lines, indices=q.gather)
            out = (_un_view(banked, q.payload.dtype) if fold == 1
                   else _unfold_view(banked, q.payload.dtype))
            return out.reshape((q.groups, n, n) + q.rest_shape)
        banked = view(q.payload, 3)                        # [G, N, N, w/f]
        into = view(q.into, 2)                             # [L, N, w/f]
        moved = self.fabric.write_burst(banked, indices=q.scatter, into=into)
        out = (_un_view(moved, q.payload.dtype) if fold == 1
               else _unfold_view(moved, q.payload.dtype))
        return out.reshape(q.into.shape)

    def _run_sparse_sharded(self, q: _Queued, read: bool) -> jax.Array:
        """One pool-sharded sparse stream through the two-hop collective
        lowering (:meth:`repro.fabric.Fabric.read_burst_sharded` /
        :meth:`~repro.fabric.Fabric.write_burst_sharded`): every shard runs
        the fused gather/scatter kernel on the frames it owns and one
        collective bridges them.  Machine-word folding applies exactly as on
        the single-device kernel path (within-line, the indices address
        whole frames), so the collective also moves ``1/fold`` the lanes."""
        n = self.fabric.n_ports
        fetch, place, k_tot = q.shard
        s, _, cap = fetch.shape
        fold = self._sparse_fold(q)
        elems = q.groups * n * n * q.width
        self.stats.network_calls += 1
        self.stats.collective_calls += 1
        self.stats.gather_fused_bursts += 1
        if self.fabric.burst_kernelized_for(q.payload.dtype):
            self.stats.kernel_bursts += 1
        self.stats.words_moved += elems
        self.stats.words_folded += elems - elems // fold
        # the exchange moves whole padded buckets; the diagonal stays local
        self.stats.words_cross_shard += s * (s - 1) * cap * n * q.width
        wide = (machine_word_dtype(
            jnp.dtype(q.payload.dtype).itemsize * fold) if fold > 1 else None)

        def view(x):
            flat = x.reshape(x.shape[:3] + (q.width,))
            if fold == 1:
                return _int_view(flat)
            return jax.lax.bitcast_convert_type(
                flat.reshape(flat.shape[:-1] + (q.width // fold, fold)), wide)

        if read:
            stream = view(q.payload)                       # [R, F, N, w/f]
            banked = self.fabric.read_burst_sharded(stream, fetch, place,
                                                    k_tot)
            out = (_un_view(banked, q.payload.dtype) if fold == 1
                   else _unfold_view(banked, q.payload.dtype))
            return out.reshape((q.groups, n, n) + q.rest_shape)
        banked = view(q.payload)                           # [G, N, N, w/f]
        into = view(q.into)                                # [R, F, N, w/f]
        moved = self.fabric.write_burst_sharded(banked, fetch, place, into)
        out = (_un_view(moved, q.payload.dtype) if fold == 1
               else _unfold_view(moved, q.payload.dtype))
        return out.reshape(q.into.shape)

    def _fold_factor(self, dtype, supports) -> int:
        """The one fold-policy choke point: the largest ``f ≤ word_fold``
        for which an ``f``-words-wide machine word exists (u64 needs x64)
        and the caller's geometry predicate ``supports(f)`` holds; 1 = no
        folding (bool/complex payloads never fold — bitcast rejects them).
        The packed, pad and sparse-kernel paths differ only in the
        predicate."""
        cap = 4 if self.word_fold == "auto" else int(self.word_fold)
        dt = jnp.dtype(dtype)
        if (cap == 1 or jnp.issubdtype(dt, jnp.bool_)
                or jnp.issubdtype(dt, jnp.complexfloating)):
            return 1
        for f in (4, 2):
            if (f <= cap and machine_word_dtype(dt.itemsize * f) is not None
                    and supports(f)):
                return f
        return 1

    def _group_fold(self, streams: List[_Queued]) -> int:
        """Fold factor for one packed dtype group: every member stream's
        geometry must divide — ``f`` divides the per-group word count (fold
        within the line group) or the group count (fold across groups)."""
        return self._fold_factor(
            streams[0].payload.dtype,
            lambda f: all(q.width % f == 0 or q.groups % f == 0
                          for q in streams))

    def _run_packed(self, streams: List[_Queued],
                    read: bool) -> Dict[str, jax.Array]:
        """Word-axis packing: fold each stream's group axis into the word
        axis (``[k*N, N, W] ≡ [N, N, k*W]`` — words of a line move
        independently), concatenate along words, run the network once on the
        ``[N, N, W_total]`` tile, and slice each stream's extent back.

        Payloads travel as machine words: the networks are pure word
        movement (block swaps/selects/gathers, no arithmetic), so each
        stream is bitcast to the same-width unsigned integer for the
        transfer and back on arrival — bit-exact by construction, and it
        keeps the burst off XLA:CPU's slow-path bf16 concatenate/select
        kernels.  Under ``word_fold`` the bitcast widens instead: adjacent
        narrow words fold into one u32/u64 machine word — the same bits in
        ``1/fold`` the lanes through every exchange stage — applied per
        stream as part of the packing view (within the line group, or
        across groups when the width is odd), with an exact unfolding
        bitcast on arrival.  The burst runs through the fabric's
        first-class burst path: one fused kernel launch per direction per
        dtype when kernels are enabled."""
        n = self.fabric.n_ports
        fold = self._group_fold(streams)
        tiles = []
        for q in streams:
            tiles.append(_pack_tile(q, n, fold))
            elems = q.groups * n * n * q.width
            self.stats.words_moved += elems
            self.stats.words_folded += elems - elems // fold
        burst = tiles[0] if len(tiles) == 1 else jnp.concatenate(tiles, axis=-1)
        moved = (self.fabric.read_burst(burst) if read
                 else self.fabric.write_burst(burst))
        if self.fabric.burst_kernelized_for(burst.dtype):
            self.stats.kernel_bursts += 1
        out: Dict[str, jax.Array] = {}
        # extents recomputed over the streams actually packed: when the
        # kernelized sparse streams peel off into their own fused launches,
        # the dense remainder's enqueue-time offsets no longer describe this
        # burst (for an unpeeled group they coincide with the spec extents)
        off = 0
        for q in streams:
            piece = moved[:, :, off // fold: (off + q.spec.words) // fold]
            off += q.spec.words
            out[q.spec.name] = _unpack_tile(piece, q, n, read, fold)
        return out

    def _padded_fold(self, streams: List[_Queued], w_max: int) -> int:
        """Fold factor for one pad-layout dtype group: every stream is
        padded to ``w_max`` words, so the factor just has to divide
        ``w_max``.  At 1 the pad path keeps its raw payload dtype, so the
        PR 1 baseline measurement is unchanged."""
        return self._fold_factor(streams[0].payload.dtype,
                                 lambda f: w_max % f == 0)

    def _run_padded(self, streams: List[_Queued],
                    read: bool) -> Dict[str, jax.Array]:
        """Pad-to-widest fallback (``pack="pad"``): streams concatenate along
        the line axis after zero-padding narrower words to the widest — the
        network moves the padding, which is what packed mode eliminates.
        Under ``word_fold`` the padded word axis folds into wider machine
        words before the network runs, same as the packed layout, so the
        pack A/B isolates the packing effect from the lane width."""
        n = self.fabric.n_ports
        out: Dict[str, jax.Array] = {}
        w_max = max(q.width for q in streams)
        fold = self._padded_fold(streams, w_max)
        wide = (machine_word_dtype(
            jnp.dtype(streams[0].payload.dtype).itemsize * fold)
            if fold > 1 else None)
        flat = []
        for q in streams:
            lead = q.payload.shape[:2] if read else q.payload.shape[:3]
            x = q.payload.reshape(lead + (q.width,))
            lines = q.payload.shape[0] * (1 if read else n)
            self.stats.words_moved += lines * n * q.width
            self.stats.words_padded += lines * n * (w_max - q.width)
            if q.width < w_max:
                pad = [(0, 0)] * (x.ndim - 1) + [(0, w_max - q.width)]
                x = jnp.pad(x, pad)
            if fold > 1:
                elems = lines * n * w_max          # lane view incl. padding
                self.stats.words_folded += elems - elems // fold
                x = jax.lax.bitcast_convert_type(
                    x.reshape(x.shape[:-1] + (w_max // fold, fold)), wide)
            flat.append(x)
        burst = jnp.concatenate(flat, axis=0)
        moved = self.fabric.read(burst) if read else self.fabric.write(burst)
        # split back: stream i covers groups [off, off + L_i/N) (read) or
        # lines [off, off + G_i*N) (write)
        off = 0
        for q in streams:
            count = (q.payload.shape[0] // n if read
                     else q.payload.shape[0] * n)
            piece = moved[off:off + count]
            off += count
            if fold > 1:
                piece = _unfold_view(piece, q.payload.dtype)
            piece = piece[..., :q.width]
            out[q.spec.name] = piece.reshape(piece.shape[:-1] + q.rest_shape)
        return out


# Sparse-extent sentinel: any index >= the backing stream's line count reads
# as a zero frame (take mode="fill") and drops on scatter (mode="drop").
# Producers (engine live plans, admission, tests) and consumers (kernels,
# fabric, scheduler) share this one value so it stays >= every pool's lines.
FRAME_SENTINEL = 2 ** 30


_WORD_VIEW = {1: jnp.uint8, 2: jnp.uint16, 4: jnp.uint32, 8: jnp.uint64}


def machine_word_dtype(itemsize: int):
    """The unsigned machine word of ``itemsize`` bytes, or None if the
    platform doesn't move one (u64 exists only under x64 — without it jax
    canonicalizes uint64 away, so float64 payloads and 8-byte folds skip
    the integer-view fast path)."""
    if itemsize == 8 and not jax.config.read("jax_enable_x64"):
        return None
    return _WORD_VIEW.get(itemsize)


def _int_view(x: jax.Array) -> jax.Array:
    """Same-width unsigned-integer view of a payload (identity for ints,
    for widths without a same-size unsigned view, and for dtypes bitcast
    rejects — bool and complex)."""
    if (jnp.issubdtype(x.dtype, jnp.integer)
            or jnp.issubdtype(x.dtype, jnp.bool_)
            or jnp.issubdtype(x.dtype, jnp.complexfloating)):
        return x
    wide = machine_word_dtype(jnp.dtype(x.dtype).itemsize)
    return x if wide is None else jax.lax.bitcast_convert_type(x, wide)


def _un_view(x: jax.Array, dtype) -> jax.Array:
    """Undo :func:`_int_view` on arrival."""
    return x if x.dtype == jnp.dtype(dtype) else (
        jax.lax.bitcast_convert_type(x, dtype))


def _pack_tile(q: _Queued, n: int, fold: int) -> jax.Array:
    """One stream → its ``[N, N, words/fold]`` extent of the packed burst.

    ``fold == 1``: the line groups fold into the word axis behind a
    same-width integer view.  ``fold > 1``: the bitcast widens instead —
    adjacent words of a line group (when ``fold`` divides the stream's
    width), or corresponding words of adjacent groups (word-major tile
    order, when ``fold`` divides the group count)."""
    g, w = q.groups, q.width
    flat = q.payload.reshape(g, n, n, w)
    if fold == 1:
        return _int_view(flat).transpose(1, 2, 0, 3).reshape(n, n, -1)
    wide = machine_word_dtype(jnp.dtype(q.payload.dtype).itemsize * fold)
    if w % fold == 0:
        folded = jax.lax.bitcast_convert_type(
            flat.reshape(g, n, n, w // fold, fold), wide)
        return folded.transpose(1, 2, 0, 3).reshape(n, n, -1)
    grouped = flat.transpose(1, 2, 3, 0).reshape(n, n, w, g // fold, fold)
    return jax.lax.bitcast_convert_type(grouped, wide).reshape(n, n, -1)


def _unpack_tile(piece: jax.Array, q: _Queued, n: int, read: bool,
                 fold: int) -> jax.Array:
    """Inverse of :func:`_pack_tile`: the stream's slice of the moved burst
    (``[N, N, words/fold]``) back to the consumer's layout — banked
    ``[G, N, N, *rest]`` for reads, lines ``[G*N, N, *rest]`` for writes."""
    g, w = q.groups, q.width
    lead = (g, n, n) if read else (g * n, n)
    if fold == 1:
        out = piece.reshape(n, n, g, w).transpose(2, 0, 1, 3)
        return _un_view(out, q.payload.dtype).reshape(lead + q.rest_shape)
    if w % fold == 0:
        out = piece.reshape(n, n, g, w // fold).transpose(2, 0, 1, 3)
        return _unfold_view(out, q.payload.dtype).reshape(lead + q.rest_shape)
    out = _unfold_view(piece.reshape(n, n, w, g // fold), q.payload.dtype)
    return out.transpose(3, 0, 1, 2).reshape(lead + q.rest_shape)


def _unfold_view(x: jax.Array, dtype) -> jax.Array:
    """Bitcast a folded machine-word array back to ``dtype``, flattening the
    ``fold``-sized axis the bitcast appends into the last dimension."""
    y = jax.lax.bitcast_convert_type(x, dtype)
    return y.reshape(y.shape[:-2] + (y.shape[-2] * y.shape[-1],))


def _prod(shape: Tuple[int, ...]) -> int:
    p = 1
    for s in shape:
        p *= s
    return p
