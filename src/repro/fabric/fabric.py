"""The ``Fabric``: one object for every memory movement in the framework.

Absorbs the old :class:`repro.core.interconnect.Interconnect` and the ad-hoc
per-consumer plumbing (KV layout engine in ``models/common.py``, MoE payload
routing, benchmark drivers).  A ``Fabric`` is built from a
:class:`repro.configs.base.FabricConfig` and exposes the paper's two data
transfer networks plus the layout/routing primitives consumers actually use:

* :meth:`read` / :meth:`write` — W_line line stream ↔ N banked port streams
  (paper §III-A), implementation selected by ``config.impl``;
* :meth:`swap_minor` — the rectangular layout engine (minor-axes transpose
  through square exchange-network tiles);
* :meth:`kv_port_major` — the production KV-cache application: line-major
  ``[B, T, H, D]`` → port-major ``[B, H, T, D]`` (Pallas kernel on the
  medusa fabric when enabled);
* :meth:`route` — explicit index routing for data-dependent traffic.
  Data-dependent destinations cannot use the static diagonal schedule, so
  every impl routes through the same gather — the fabric still owns the
  call so the op census has one choke point.  Since the sparse-extent
  burst contract (``read_burst(indices=)`` / ``write_burst(indices=,
  into=)``) landed, production consumers express data-dependent movement
  as indexed streams on the scheduler instead — MoE top-k
  dispatch/combine (:func:`repro.models.moe.moe_apply`) rides it, and
  ``route`` remains as the uncounted A/B reference those streams are
  asserted bit-identical against.

All impls are value-identical; they differ only in the HLO they lower to,
which is what the paper's FPGA resource comparison becomes on TPU.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.configs.base import FabricConfig
from repro.core import baseline as _b
from repro.core import transpose as _t
from repro.kernels import ops as kops


def pm_to_banked(pm: jax.Array, n: int) -> jax.Array:
    """Port-major streams ``[N, L, D]`` (one deep-narrow stream per port) →
    the banked ``[G, N, N, D]`` buffer the write network consumes — the one
    place the banked layout invariant lives (``write ∘ pm_to_banked`` is
    the identity on the corresponding ``[L, N, D]`` line stream).  Consumers:
    ``models.common.port_major_to_banked`` (scheduled decode) and
    ``PagedKVCache`` (burst-installed prefill)."""
    l, d = pm.shape[1], pm.shape[-1]
    return pm.reshape(n, l // n, n, d).transpose(1, 0, 2, 3)


@dataclasses.dataclass(frozen=True)
class Fabric:
    """A W_line ↔ N x W_acc memory-movement fabric with selectable network."""

    config: FabricConfig
    #: the jax device mesh carrying the ``pool`` axis when
    #: ``config.pool_shards > 1`` (``repro.fabric.sharded.make_pool_mesh``);
    #: None on the single-device fabric.
    mesh: "object | None" = dataclasses.field(default=None, compare=False)

    @classmethod
    def for_model(cls, cfg) -> "Fabric":
        """The fabric a :class:`repro.configs.base.ModelConfig` names."""
        return cls(cfg.resolved_fabric)

    @classmethod
    def make(cls, n_ports: int, impl: str = "medusa", **kw) -> "Fabric":
        return cls(FabricConfig(n_ports=n_ports, impl=impl, **kw).validate())

    # -- geometry -------------------------------------------------------------
    @property
    def n_ports(self) -> int:
        return self.config.n_ports

    @property
    def impl(self) -> str:
        return self.config.impl

    @property
    def pack(self) -> str:
        """Burst layout the scheduler uses on this fabric (packed | pad)."""
        return self.config.pack

    @property
    def latency_cycles(self) -> int:
        """Constant pipeline latency of the transposition unit (§III-E)."""
        return _t.transposition_latency_cycles(self.config.n_ports)

    @property
    def banks_kv(self) -> bool:
        """Whether this fabric banks KV traffic through the read/write
        networks at all — the ``fused`` impl contracts consumers directly
        against line-major caches, so routing KV through the networks would
        materialize exactly the copies it elides (burst-scheduled decode
        and burst-installed prefill both gate on this)."""
        return self.impl != "fused"

    # -- the two data-transfer networks (paper §III-A) ------------------------
    def read(self, lines: jax.Array) -> jax.Array:
        """Read network: DRAM line stream ``[L, N, W]`` → banked port buffer
        ``[G, N(word-addr), N(port-lane), W]``."""
        n = self.config.n_ports
        if self.impl == "medusa":
            return _t.read_network_medusa(lines, n)
        if self.impl == "crossbar":
            return _b.read_network_crossbar(lines, n)
        return _t.read_network_oracle(lines, n)

    def write(self, banked: jax.Array) -> jax.Array:
        """Write network: banked port buffer → DRAM line stream."""
        n = self.config.n_ports
        if self.impl == "medusa":
            return _t.write_network_medusa(banked, n)
        if self.impl == "crossbar":
            return _b.write_network_crossbar(banked, n)
        return _t.write_network_oracle(banked, n)

    # -- first-class bursts (the scheduler's hot path) -------------------------
    @property
    def burst_kernelized(self) -> bool:
        """Whether :meth:`read_burst`/:meth:`write_burst` lower through the
        fused Pallas kernel (medusa impl, kernels enabled, power-of-two N)."""
        n = self.config.n_ports
        return (self.impl == "medusa" and kops.kernels_enabled()
                and n >= 2 and n & (n - 1) == 0)

    def burst_kernelized_for(self, dtype) -> bool:
        """:attr:`burst_kernelized`, per payload dtype: complex payloads
        stay on the unrolled path (Pallas interpret on this jax cannot
        stage complex buffers)."""
        return (self.burst_kernelized
                and not jnp.issubdtype(jnp.dtype(dtype), jnp.complexfloating))

    def read_burst(self, burst: jax.Array,
                   indices: "jax.Array | None" = None) -> jax.Array:
        """One packed ``[N, N, W]`` read-burst tile (N lines of N machine
        words, W payload lanes — every queued stream of a dtype, word-packed
        by the scheduler) → banked ``[N, N, W]``.  On the medusa fabric with
        kernels enabled this is ONE fused ``pallas_call`` (word-tiled grid);
        otherwise the per-stage network of :meth:`read` on the single tile.

        With ``indices`` the burst is a **sparse-extent** transfer (the
        fused page-table gather): ``burst`` is a full pool line stream
        ``[L, N, W]`` and ``indices [K]`` (K a multiple of N; entries
        ``>= L`` are sentinels reading as zero frames) names the live
        frames — the network banks only those, returning ``[K//N, N, N,
        W]``.  Kernelized, the indices ride the launch as a prefetched
        operand (indirection + exchange in one kernel, no materialized
        full-pool intermediate); unrolled, the gather lowers as a take
        feeding the per-stage network.  Either way the network's traffic is
        ``K`` frames — live tokens, not pool capacity."""
        n = self.config.n_ports
        if indices is not None:
            if burst.ndim != 3 or burst.shape[1] != n:
                raise ValueError(f"sparse read wants pool lines [L, N, W] "
                                 f"for N={n}, got {burst.shape}")
            if indices.shape[0] % n:
                raise ValueError(f"gather index count {indices.shape[0]} "
                                 f"must be a multiple of N={n}")
            if self.burst_kernelized_for(burst.dtype):
                return kops.burst_gather_read(burst, indices, n)
            taken = jnp.take(burst, indices, axis=0, mode="fill",
                             fill_value=0)
            return self.read(taken)
        self._check_burst(burst)
        if self.burst_kernelized_for(burst.dtype):
            return kops.burst_read(burst, n)
        return self.read(burst)[0]

    def write_burst(self, banked: jax.Array,
                    indices: "jax.Array | None" = None,
                    into: "jax.Array | None" = None) -> jax.Array:
        """Write direction of :meth:`read_burst`: one banked ``[N, N, W]``
        tile → the ``[N, N, W]`` line tile headed back to DRAM.

        With ``indices`` (and ``into``, the pool line stream ``[L, N, W]``
        being written) this is the sparse-extent scatter: ``banked`` is
        ``[G, N, N, W]`` of live frames, the write network reassembles their
        lines, and each lands at its indexed pool row (sentinels drop; rows
        the indices never touch keep their frames without moving — the
        kernelized form is one input-output-aliased launch).  Returns the
        updated pool stream."""
        n = self.config.n_ports
        if indices is not None:
            if into is None:
                raise ValueError("sparse write_burst needs the pool stream "
                                 "to scatter into (into=)")
            if banked.ndim != 4 or banked.shape[1] != n or banked.shape[2] != n:
                raise ValueError(f"sparse write wants banked [G, N, N, W] "
                                 f"for N={n}, got {banked.shape}")
            if indices.shape[0] != banked.shape[0] * n:
                raise ValueError(f"scatter index count {indices.shape[0]} "
                                 f"!= banked line count {banked.shape[0] * n}")
            if self.burst_kernelized_for(banked.dtype):
                return kops.burst_scatter_write(banked, indices, into, n)
            lines = self.write(banked)
            return into.at[indices].set(lines, mode="drop")
        self._check_burst(banked)
        if self.burst_kernelized_for(banked.dtype):
            return kops.burst_write(banked, n)
        return self.write(banked[None])

    # -- device-mesh lowering (the sharded pool) -------------------------------
    @property
    def pool_sharded(self) -> bool:
        """Whether sparse bursts lower as the two-hop collective over the
        ``pool`` mesh axis (``config.pool_shards > 1`` and a mesh bound)."""
        return self.config.pool_shards > 1 and self.mesh is not None

    def read_burst_sharded(self, stream: jax.Array, fetch: jax.Array,
                           place: jax.Array, k_tot: int) -> jax.Array:
        """Sparse read burst over the pool-sharded line stream ``[R, F, N,
        W]`` — each shard fuse-gathers its owned frames (:meth:`read_burst`
        with the plan's ``fetch`` indices), one collective delivers them,
        and the result is the same banked ``[k_tot//N, N, N, W]`` the
        single-device sparse read produces, bit for bit.  The ``fetch`` /
        ``place`` operands come from ``repro.fabric.sharded.shard_plan``."""
        from repro.fabric import sharded as _sh
        return _sh.sharded_read_burst(self, stream, fetch, place, k_tot)

    def write_burst_sharded(self, banked: jax.Array, fetch: jax.Array,
                            place: jax.Array, into: jax.Array) -> jax.Array:
        """Write direction of :meth:`read_burst_sharded`: the same plan run
        in reverse lands each banked live frame at its owning shard's pool
        row (local fused scatter after the collective hop)."""
        from repro.fabric import sharded as _sh
        return _sh.sharded_write_burst(self, banked, fetch, place, into)

    def _check_burst(self, tile: jax.Array) -> None:
        n = self.config.n_ports
        if tile.ndim != 3 or tile.shape[0] != n or tile.shape[1] != n:
            raise ValueError(
                f"burst tile must be [N, N, W] for N={n}, got {tile.shape}")

    # -- layout engine --------------------------------------------------------
    def swap_minor(self, x: jax.Array) -> jax.Array:
        """Transpose the two minor axes of ``x`` (rectangular OK) — e.g.
        KV cache [T, H*D-line] ↔ [H, T-stream] — on the selected network."""
        if self.impl == "medusa":
            return _t.medusa_swap_minor(x, tile=self.config.tile)
        if self.impl == "crossbar":
            r, c = x.shape[-2], x.shape[-1]
            i = jax.lax.broadcasted_iota(jnp.int32, x.shape[:-2] + (c, r),
                                         x.ndim - 2)
            j = jax.lax.broadcasted_iota(jnp.int32, x.shape[:-2] + (c, r),
                                         x.ndim - 1)
            flat = x.reshape(x.shape[:-2] + (r * c,))
            return jnp.take_along_axis(
                flat, (j * c + i).reshape(x.shape[:-2] + (c * r,)),
                axis=-1).reshape(x.shape[:-2] + (c, r))
        return _t.transpose_oracle(x, x.ndim - 2, x.ndim - 1)

    def kv_port_major(self, c: jax.Array) -> jax.Array:
        """KV-cache layout engine: line-major ``[B, T, Hkv, D]`` (one timestep
        = one wide line across heads) → port-major ``[B, Hkv, T, D]`` (one
        deep-narrow stream per head).  The production read-network
        application; on the medusa fabric this is the Pallas exchange-network
        kernel when kernels are enabled.  The "fused" fabric never calls
        this — its consumers contract against the line-major cache directly.
        """
        if self.impl == "medusa" and kops.kernels_enabled():
            return jax.vmap(kops.kv_line_to_port)(c)
        if self.impl == "crossbar":
            # over-provisioned routing: explicit gather through an index tensor
            b, t, hkv, d = c.shape
            flat = c.reshape(b, t * hkv, d)
            idx = (jnp.arange(hkv)[:, None]
                   + jnp.arange(t)[None, :] * hkv).reshape(-1)
            return jnp.take(flat, idx, axis=1).reshape(b, hkv, t, d)
        return jnp.swapaxes(c, 1, 2)

    # -- data-dependent routing ----------------------------------------------
    def route(self, data: jax.Array, index: jax.Array,
              axis: int = 0) -> jax.Array:
        """Gather ``data`` rows through an explicit ``index`` tensor — the
        crossbar primitive for data-dependent destinations.  Identical
        across impls by construction.  MoE top-k staging/combine now rides
        the scheduler's indexed burst streams instead (counted, shared
        lowering); this stays as the bit-parity reference and the fallback
        for fabrics that don't bank (``impl="fused"``)."""
        return jnp.take(data, index, axis=axis)
