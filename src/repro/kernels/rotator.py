"""Pallas TPU kernel: the barrel rotation unit (paper §III-B, Fig. 5).

Rotates ``N`` port-words by a per-group dynamic amount using ``log2(N)``
stages; stage ``l`` is a *static* roll by ``2**l`` (slice+concat — a full-width
vector move) selected by bit ``l`` of the rotation amount, read from SMEM via
scalar prefetch.  A data-dependent rotation thus never emits a gather: the
dynamic part is only in the per-stage select bit, exactly like the FPGA
barrel shifter whose stage enables come from the cycle counter.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


@functools.partial(jax.jit, static_argnames=("interpret",))
def barrel_rotate_groups(x: jax.Array, amounts: jax.Array,
                         interpret: bool = True) -> jax.Array:
    """Left-rotate each group ``x[g] : [N, W]`` by ``amounts[g]`` positions.

    ``N`` must be a power of two.  Grid over groups; the rotation amount is a
    scalar-prefetch operand (SMEM), the data rides in VMEM blocks.
    """
    g, n, w = x.shape
    if n & (n - 1):
        raise ValueError(f"N={n} must be a power of two")
    amounts = amounts.astype(jnp.int32)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(g,),
        in_specs=[pl.BlockSpec((1, n, w), lambda i, amt: (i, 0, 0))],
        out_specs=pl.BlockSpec((1, n, w), lambda i, amt: (i, 0, 0)),
    )

    def kernel(amt_ref, x_ref, o_ref):
        x_blk = x_ref[...]
        i = pl.program_id(0)
        amount = amt_ref[i] % n
        for level in range(int(math.log2(n))):
            bit = ((amount >> level) & 1) == 1
            rolled = jnp.roll(x_blk, -(1 << level), axis=1)
            x_blk = jnp.where(bit, rolled, x_blk)
        o_ref[...] = x_blk

    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((g, n, w), x.dtype),
        interpret=interpret,
    )(amounts, x)
