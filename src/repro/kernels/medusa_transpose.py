"""Pallas TPU kernel: Medusa transposition unit on VMEM tiles.

The paper's transposition unit moves a ``W_line``-bit line per cycle between
lane-banked and port-banked layouts using a barrel rotator instead of a
crossbar.  On TPU the equivalent hot spot is the (sublane, lane) transpose of
VMEM tiles in the layout-conversion path (KV cache line-major → head-major,
banked weight streams, interconnect re-banking).  This kernel performs it with
the binary-exchange network: ``log2(T)`` stages, each one *static* roll (a
full-width vector move — the VPU analogue of a barrel-shifter layer) plus a
2-to-1 select on iota masks.  No gathers and no index tensors are emitted,
which is exactly the resource contrast the paper draws against crossbars.

Layout contract: operands are ``[R, C, W]`` with payload ``W`` innermost
(lanes; use W multiple of 128 on hardware) and the transposed pair in the two
leading dims (sublanes).  Grid tiles are square ``T x T`` with ``T`` a power
of two; block (i, j) of the input writes block (j, i) of the output — the tile
*grid* transpose is free (BlockSpec index maps), the intra-tile movement is
the exchange network.

:func:`burst_network_tiles` is the burst-scheduler entry point: one packed
``[N, N, W_total]`` burst tile (every queued stream of a dtype, word-packed)
moves through a single ``pallas_call`` with a word-tiled grid — the whole
§III-A transposition as one kernel launch per direction per dtype, instead of
the unrolled per-stage HLO chain.  The square-tile network is an involution,
so the same kernel serves both the read (lines → banked) and write (banked →
lines) directions; only the surrounding group reshapes differ.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.transpose import _bit_flip_both, _swap_mask


def _exchange_stage(tile: jax.Array, a0: int, a1: int, level: int) -> jax.Array:
    """One exchange stage: swap bit ``level`` between the ``a0``/``a1``
    indices.  The partner value sits at both bits flipped — a static bit-flip
    block swap (:func:`repro.core.transpose._bit_flip_both`, the wiring of
    one barrel-shifter layer) — picked by a 2-to-1 select on the stage's
    static mux pattern.  The mask is built from an in-kernel iota (a Pallas
    kernel body cannot capture host constants); it is xor-symmetric, so
    axis order is free."""
    n = tile.shape[a0]
    flipped = _bit_flip_both(tile, a0, a1, level)
    row = jax.lax.broadcasted_iota(jnp.int32, (n, n), 0)
    col = jax.lax.broadcasted_iota(jnp.int32, (n, n), 1)
    mshape = [1] * tile.ndim
    mshape[min(a0, a1)], mshape[max(a0, a1)] = n, n
    mask = ((((row ^ col) >> level) & 1) == 1).reshape(mshape)
    return jnp.where(mask, flipped, tile)


def _exchange_network(tile: jax.Array) -> jax.Array:
    """log2(T)-stage binary-exchange transpose of ``tile [T, T, W]``."""
    for level in range(int(math.log2(tile.shape[0]))):
        tile = _exchange_stage(tile, 0, 1, level)
    return tile


def _transpose_kernel(x_ref, o_ref):
    o_ref[...] = _exchange_network(x_ref[...])


@functools.partial(jax.jit, static_argnames=("tile", "interpret"))
def medusa_transpose_tiles(x: jax.Array, tile: int = 8,
                           interpret: bool = True) -> jax.Array:
    """Transpose the two leading axes of ``x [R, C, W]`` → ``[C, R, W]``.

    ``R`` and ``C`` must be multiples of ``tile`` (a power of two); ``ops.py``
    wraps this with padding for arbitrary shapes.  ``W`` rides along in lanes.
    On hardware use ``tile`` >= the sublane count for the dtype and ``W`` a
    multiple of 128; ``interpret=True`` runs the same kernel body on CPU.
    """
    r, c, w = x.shape
    if r % tile or c % tile:
        raise ValueError(f"R={r}, C={c} must be multiples of tile={tile}")
    if tile & (tile - 1):
        raise ValueError(f"tile must be a power of two, got {tile}")
    grid = (r // tile, c // tile)
    return pl.pallas_call(
        _transpose_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((tile, tile, w), lambda i, j: (i, j, 0))],
        out_specs=pl.BlockSpec((tile, tile, w), lambda i, j: (j, i, 0)),
        out_shape=jax.ShapeDtypeStruct((c, r, w), x.dtype),
        interpret=interpret,
    )(x)


def _exchange_network_nd(tile: jax.Array, a0: int, a1: int) -> jax.Array:
    """Exchange network over an arbitrary axis pair (payload elsewhere)."""
    for level in range(int(math.log2(tile.shape[a0]))):
        tile = _exchange_stage(tile, a0, a1, level)
    return tile


def _rebank_kernel(x_ref, o_ref):
    # One interconnect group per grid step: [1, N(line=port), N(word), W] →
    # banked [1, N(word-addr), N(port-lane), W] — the §III-A read transposition.
    o_ref[...] = _exchange_network_nd(x_ref[...], 1, 2)


@functools.partial(jax.jit, static_argnames=("n_ports", "interpret"))
def read_network_tiles(lines: jax.Array, n_ports: int,
                       interpret: bool = True) -> jax.Array:
    """Kernel form of :func:`repro.core.transpose.read_network_medusa`:
    ``lines [L, N, W]`` → banked ``[G, N, N, W]``; one group tile per grid
    step, double-buffered by the Pallas pipeline (the paper's prefetch)."""
    n = n_ports
    l, n_words, w = lines.shape
    if n_words != n or l % n:
        raise ValueError(f"bad line stream {lines.shape} for N={n}")
    groups = l // n
    x = lines.reshape(groups, n, n, w)
    return pl.pallas_call(
        _rebank_kernel,
        grid=(groups,),
        in_specs=[pl.BlockSpec((1, n, n, w), lambda g: (g, 0, 0, 0))],
        out_specs=pl.BlockSpec((1, n, n, w), lambda g: (g, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((groups, n, n, w), lines.dtype),
        interpret=interpret,
    )(x)


def _pick_word_tile(w: int, cap: int = 4096, divisor: bool = False) -> int:
    """Word-tile for a burst of ``w`` lanes: the whole burst when it fits,
    else the largest divisor of ``w`` in (cap/2, cap] (one clean grid), else
    the evenest split at the same grid depth — ``ceil(w / ceil(w/cap))``
    pads at most ``grid-1`` lanes total instead of up to ``cap-1``.

    ``divisor=True`` is the gather-operand mode: the tile must DIVIDE ``w``
    so the index operand tiles cleanly with the word grid.  The gather and
    scatter burst kernels address whole frames through a prefetched index
    list; a padded edge tile would read (and, on the aliased scatter, write)
    past the frame's word extent at an indexed row — so instead of the pad
    fallback the search widens to the largest divisor ≤ cap (worst case 1
    for a prime ``w``; pick lane counts that factor, on hardware multiples
    of 128)."""
    if w <= cap:
        return w
    for t in range(cap, cap // 2, -1):
        if w % t == 0:
            return t
    if divisor:
        return max(t for t in range(1, cap // 2 + 1) if w % t == 0)
    grid = -(-w // cap)
    return -(-w // grid)


def _stage_masks(n: int):
    """The exchange network's static mux patterns, one ``[N, N, 1]`` bool
    mask per stage (:func:`repro.core.transpose._swap_mask`).  Passed to
    the burst kernel as operands — SMEM-sized control state, the
    compile-time wiring of the paper's muxes — because a Pallas body cannot
    capture array constants and building them in-body from iotas costs
    more than it says."""
    return tuple(_swap_mask(3, n, 0, 1, level)
                 for level in range(int(math.log2(n))))


def _burst_kernel(*refs):
    # One word tile per grid step: [N, N, tw] through the exchange network —
    # on hardware the Pallas pipeline double-buffers consecutive word tiles
    # through VMEM (the paper's §III-C prefetch) while the VPU exchanges the
    # resident one.  refs = (x, mask_0 .. mask_{stages-1}, out).
    x_ref, o_ref = refs[0], refs[-1]
    tile = x_ref[...]
    for level, m_ref in enumerate(refs[1:-1]):
        tile = jnp.where(m_ref[...], _bit_flip_both(tile, 0, 1, level), tile)
    o_ref[...] = tile


@functools.partial(jax.jit, static_argnames=("n_ports", "word_tile",
                                             "interpret"))
def burst_network_tiles(tile: jax.Array, n_ports: int, word_tile: int = 0,
                        interpret: bool = True) -> jax.Array:
    """One packed burst ``[N, N, W]`` through the transposition unit as a
    single fused kernel — the whole burst is one launch per direction per
    dtype (vs the unrolled per-stage HLO chain of
    :func:`repro.core.transpose.medusa_transpose`).

    The square ``[N, N]`` exchange is an involution, so the same kernel is
    the read network (``lines[p, y] → banked[y, p]``) and the write network
    (banked → lines); callers do their own group reshapes.  The grid tiles
    the word axis: ``word_tile`` lanes per step, default the whole burst
    when it fits a VMEM block (W ≤ 4096), else the largest divisor of W
    near 4096 (or 4096 with pad, sliced off after — VMEM tiling fill, not
    network traffic).  ``interpret=True`` runs the same body on CPU."""
    n = n_ports
    if tile.ndim != 3 or tile.shape[0] != n or tile.shape[1] != n:
        raise ValueError(f"bad burst tile {tile.shape} for N={n}")
    w = tile.shape[2]
    if w == 0:
        return tile
    tw = word_tile or _pick_word_tile(w)
    pad = (-w) % tw
    x = jnp.pad(tile, ((0, 0), (0, 0), (0, pad))) if pad else tile
    masks = _stage_masks(n)
    out = pl.pallas_call(
        _burst_kernel,
        grid=((w + pad) // tw,),
        in_specs=[pl.BlockSpec((n, n, tw), lambda i: (0, 0, i))]
                 + [pl.BlockSpec((n, n, 1), lambda i: (0, 0, 0))] * len(masks),
        out_specs=pl.BlockSpec((n, n, tw), lambda i: (0, 0, i)),
        out_shape=jax.ShapeDtypeStruct((n, n, w + pad), tile.dtype),
        interpret=interpret,
    )(x, *masks)
    return out[:, :, :w] if pad else out


# ----------------------------------------------------------------------------
# fused page-table gather/scatter bursts (sparse-extent streams)
# ----------------------------------------------------------------------------
#
# The paged KV pool names its live frames through a logical→physical table;
# these kernels make that indirection part of the transposition unit itself
# (vLLM paged-attention style): the frame-index list rides the launch as a
# *scalar-prefetched* operand, the BlockSpec index maps dereference it, and
# the network banks ONLY the addressed frames — one launch that does
# indirection + exchange, with no materialized full-pool intermediate and
# traffic proportional to live tokens instead of pool capacity.  Sentinel
# indices (>= the pool's line count) gather as zero frames on the read side
# and drop on the (input-output-aliased) write side, so index lists pad to
# the N-line group granularity for free.  The index contract is
# non-negative-or-sentinel: entries must lie in [0, L) or at/above L — a
# negative entry is undefined (the unrolled take/scatter would wrap it
# NumPy-style while the kernel's block clamp would not), and every producer
# (``page_live_plan`` asserts the table's mapped-prefix invariant,
# admission maps only allocated pages, ``page_gather_indices`` rewrites
# unmapped rows to the sentinel) guarantees it by construction.

def _exchange_with_masks(tile: jax.Array, mask_refs) -> jax.Array:
    """The burst kernel's exchange network on one ``[N, N, tw]`` tile, stage
    mux patterns supplied as operands (a Pallas body cannot capture array
    constants)."""
    for level, m_ref in enumerate(mask_refs):
        tile = jnp.where(m_ref[...], _bit_flip_both(tile, 0, 1, level), tile)
    return tile


def _gather_burst_kernel(n: int, n_lines: int, *refs):
    # grid (G, Wt, N): steps r = 0..N-1 of a (group, word-tile) pair gather
    # one addressed frame each into the scratch tile; the last step runs the
    # exchange network on the assembled [N, N, tw] tile and banks it.
    idx_ref, x_ref, o_ref, scratch = refs[0], refs[1], refs[-2], refs[-1]
    g, r = pl.program_id(0), pl.program_id(2)
    valid = idx_ref[g * n + r] < n_lines
    scratch[r, :, :] = jnp.where(valid, x_ref[0], jnp.zeros_like(x_ref[0]))

    @pl.when(r == n - 1)
    def _():
        o_ref[0] = _exchange_with_masks(scratch[...], refs[2:-2])


@functools.partial(jax.jit, static_argnames=("n_ports", "word_tile",
                                             "interpret"))
def gather_burst_network_tiles(lines: jax.Array, idx: jax.Array,
                               n_ports: int, word_tile: int = 0,
                               interpret: bool = True) -> jax.Array:
    """Fused gather + read network: pool line stream ``lines [L, N, W]`` and
    frame indices ``idx [K]`` (``K`` a multiple of N; entries ``>= L`` are
    sentinels) → banked ``[K//N, N, N, W]`` holding exactly the addressed
    frames, zeros at sentinels.  The index list is a scalar-prefetched
    operand: each grid step's input block is ``lines[idx[...]]`` — the
    indirection happens in the BlockSpec index map, so only live frames move
    through VMEM and the exchange stages.  Equivalent to
    ``take(lines, idx, fill=0)`` followed by :func:`burst_network_tiles`
    groupwise, as one launch."""
    n = n_ports
    l, n_words, w = lines.shape
    k = idx.shape[0]
    if n_words != n or k % n:
        raise ValueError(f"bad gather burst: lines {lines.shape}, "
                         f"idx {idx.shape} for N={n}")
    tw = word_tile or _pick_word_tile(w, divisor=True)
    if w % tw:
        raise ValueError(
            f"gather word_tile={tw} must divide the frame word count {w} "
            f"(the index operand must tile with the word grid)")
    groups = k // n
    masks = _stage_masks(n)
    idx = idx.astype(jnp.int32)
    clamped = lambda g, wt, r, idx_ref: (
        jnp.minimum(idx_ref[g * n + r], l - 1), 0, wt)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(groups, w // tw, n),
        in_specs=[pl.BlockSpec((1, n, tw), clamped)]
                 + [pl.BlockSpec((n, n, 1), lambda g, wt, r, idx_ref:
                    (0, 0, 0))] * len(masks),
        out_specs=pl.BlockSpec((1, n, n, tw),
                               lambda g, wt, r, idx_ref: (g, 0, 0, wt)),
        scratch_shapes=[pltpu.VMEM((n, n, tw), lines.dtype)],
    )
    return pl.pallas_call(
        functools.partial(_gather_burst_kernel, n, l),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((groups, n, n, w), lines.dtype),
        interpret=interpret,
    )(idx, lines, *masks)


def _scatter_burst_kernel(n: int, n_lines: int, *refs):
    # grid (G, Wt, N): each step exchanges its group tile (the involution —
    # the write direction of the same network) and lands line r at the
    # addressed pool row; sentinel rows read-modify-write THE OUTPUT block
    # back unchanged (o_ref starts as the aliased pool and reflects earlier
    # grid steps' writes, so a sentinel clamped onto a row another entry
    # already landed on cannot resurrect the stale frame — the separate
    # dest operand exists only to carry the input-output alias).  The
    # exchange recomputes per line — log2(N) selects on a VMEM-resident
    # tile, cheap next to the DMA — which keeps the kernel scratch-free in
    # the aliased-output direction.
    idx_ref, x_ref, o_ref = refs[0], refs[1], refs[-1]
    g, r = pl.program_id(0), pl.program_id(2)
    valid = idx_ref[g * n + r] < n_lines
    tile = _exchange_with_masks(x_ref[0], refs[2:-2])
    o_ref[0] = jnp.where(valid, tile[r], o_ref[0])


@functools.partial(jax.jit, static_argnames=("n_ports", "word_tile",
                                             "interpret"))
def scatter_burst_network_tiles(banked: jax.Array, idx: jax.Array,
                                into: jax.Array, n_ports: int,
                                word_tile: int = 0,
                                interpret: bool = True) -> jax.Array:
    """Fused write network + scatter: banked ``[G, N, N, W]`` → line frames
    scattered into the pool stream ``into [L, N, W]`` at rows ``idx [G*N]``
    (sentinel entries ``>= L`` drop).  ``into`` aliases the output, so rows
    the indices never touch keep their frames without moving — the write
    traffic is the live frames only.  Grid steps are sequential (each
    revisited destination row is read-modify-written in order); on real
    hardware the sentinel clamp would need a reserved row to keep the
    pipeline hazard-free — interpret mode, the validated path, is exact."""
    n = n_ports
    g_count, n0, n1, w = banked.shape
    l = into.shape[0]
    if n0 != n or n1 != n or idx.shape[0] != g_count * n:
        raise ValueError(f"bad scatter burst: banked {banked.shape}, "
                         f"idx {idx.shape} for N={n}")
    if into.shape[1] != n or into.shape[2] != w:
        raise ValueError(f"scatter target {into.shape} does not match "
                         f"banked frames [{n}, {w}]")
    tw = word_tile or _pick_word_tile(w, divisor=True)
    if w % tw:
        raise ValueError(
            f"scatter word_tile={tw} must divide the frame word count {w} "
            f"(the index operand must tile with the word grid)")
    masks = _stage_masks(n)
    idx = idx.astype(jnp.int32)
    clamped = lambda g, wt, r, idx_ref: (
        jnp.minimum(idx_ref[g * n + r], l - 1), 0, wt)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(g_count, w // tw, n),
        in_specs=[pl.BlockSpec((1, n, n, tw),
                               lambda g, wt, r, idx_ref: (g, 0, 0, wt))]
                 + [pl.BlockSpec((n, n, 1), lambda g, wt, r, idx_ref:
                    (0, 0, 0))] * len(masks)
                 + [pl.BlockSpec((1, n, tw), clamped)],
        out_specs=pl.BlockSpec((1, n, tw), clamped),
    )
    return pl.pallas_call(
        functools.partial(_scatter_burst_kernel, n, l),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(into.shape, into.dtype),
        input_output_aliases={2 + len(masks): 0},
        interpret=interpret,
    )(idx, banked, *masks, into)
