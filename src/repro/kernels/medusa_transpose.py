"""Pallas TPU kernel: Medusa transposition unit on VMEM tiles.

The paper's transposition unit moves a ``W_line``-bit line per cycle between
lane-banked and port-banked layouts using a barrel rotator instead of a
crossbar.  On TPU the equivalent hot spot is the (sublane, lane) transpose of
VMEM tiles in the layout-conversion path (KV cache line-major → head-major,
banked weight streams, interconnect re-banking).  This kernel performs it with
the binary-exchange network: ``log2(T)`` stages, each one *static* roll (a
full-width vector move — the VPU analogue of a barrel-shifter layer) plus a
2-to-1 select on iota masks.  No gathers and no index tensors are emitted,
which is exactly the resource contrast the paper draws against crossbars.

Layout contract: operands are ``[R, C, W]`` with payload ``W`` innermost
(lanes; use W multiple of 128 on hardware) and the transposed pair in the two
leading dims (sublanes).  Grid tiles are square ``T x T`` with ``T`` a power
of two; block (i, j) of the input writes block (j, i) of the output — the tile
*grid* transpose is free (BlockSpec index maps), the intra-tile movement is
the exchange network.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _exchange_network(tile: jax.Array) -> jax.Array:
    """log2(T)-stage binary-exchange transpose of ``tile [T, T, W]``."""
    t = tile.shape[0]
    stages = int(math.log2(t))
    row = jax.lax.broadcasted_iota(jnp.int32, tile.shape, 0)
    col = jax.lax.broadcasted_iota(jnp.int32, tile.shape, 1)
    for level in range(stages):
        s = 1 << level
        rbit = (row >> level) & 1
        cbit = (col >> level) & 1
        from_down = jnp.roll(jnp.roll(tile, s, axis=0), -s, axis=1)
        from_up = jnp.roll(jnp.roll(tile, -s, axis=0), s, axis=1)
        tile = jnp.where((rbit == 1) & (cbit == 0), from_down,
                         jnp.where((rbit == 0) & (cbit == 1), from_up, tile))
    return tile


def _transpose_kernel(x_ref, o_ref):
    o_ref[...] = _exchange_network(x_ref[...])


@functools.partial(jax.jit, static_argnames=("tile", "interpret"))
def medusa_transpose_tiles(x: jax.Array, tile: int = 8,
                           interpret: bool = True) -> jax.Array:
    """Transpose the two leading axes of ``x [R, C, W]`` → ``[C, R, W]``.

    ``R`` and ``C`` must be multiples of ``tile`` (a power of two); ``ops.py``
    wraps this with padding for arbitrary shapes.  ``W`` rides along in lanes.
    On hardware use ``tile`` >= the sublane count for the dtype and ``W`` a
    multiple of 128; ``interpret=True`` runs the same kernel body on CPU.
    """
    r, c, w = x.shape
    if r % tile or c % tile:
        raise ValueError(f"R={r}, C={c} must be multiples of tile={tile}")
    if tile & (tile - 1):
        raise ValueError(f"tile must be a power of two, got {tile}")
    grid = (r // tile, c // tile)
    return pl.pallas_call(
        _transpose_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((tile, tile, w), lambda i, j: (i, j, 0))],
        out_specs=pl.BlockSpec((tile, tile, w), lambda i, j: (j, i, 0)),
        out_shape=jax.ShapeDtypeStruct((c, r, w), x.dtype),
        interpret=interpret,
    )(x)


def _exchange_network_nd(tile: jax.Array, a0: int, a1: int) -> jax.Array:
    """Exchange network over an arbitrary axis pair (payload elsewhere)."""
    t = tile.shape[a0]
    stages = int(math.log2(t))
    row = jax.lax.broadcasted_iota(jnp.int32, tile.shape, a0)
    col = jax.lax.broadcasted_iota(jnp.int32, tile.shape, a1)
    for level in range(stages):
        s = 1 << level
        rbit = (row >> level) & 1
        cbit = (col >> level) & 1
        from_down = jnp.roll(jnp.roll(tile, s, axis=a0), -s, axis=a1)
        from_up = jnp.roll(jnp.roll(tile, -s, axis=a0), s, axis=a1)
        tile = jnp.where((rbit == 1) & (cbit == 0), from_down,
                         jnp.where((rbit == 0) & (cbit == 1), from_up, tile))
    return tile


def _rebank_kernel(x_ref, o_ref):
    # One interconnect group per grid step: [1, N(line=port), N(word), W] →
    # banked [1, N(word-addr), N(port-lane), W] — the §III-A read transposition.
    o_ref[...] = _exchange_network_nd(x_ref[...], 1, 2)


@functools.partial(jax.jit, static_argnames=("n_ports", "interpret"))
def read_network_tiles(lines: jax.Array, n_ports: int,
                       interpret: bool = True) -> jax.Array:
    """Kernel form of :func:`repro.core.transpose.read_network_medusa`:
    ``lines [L, N, W]`` → banked ``[G, N, N, W]``; one group tile per grid
    step, double-buffered by the Pallas pipeline (the paper's prefetch)."""
    n = n_ports
    l, n_words, w = lines.shape
    if n_words != n or l % n:
        raise ValueError(f"bad line stream {lines.shape} for N={n}")
    groups = l // n
    x = lines.reshape(groups, n, n, w)
    return pl.pallas_call(
        _rebank_kernel,
        grid=(groups,),
        in_specs=[pl.BlockSpec((1, n, n, w), lambda g: (g, 0, 0, 0))],
        out_specs=pl.BlockSpec((1, n, n, w), lambda g: (g, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((groups, n, n, w), lines.dtype),
        interpret=interpret,
    )(x)
