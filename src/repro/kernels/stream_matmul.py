"""Pallas TPU kernel: double-buffered streaming matmul (the layer processor).

The paper's evaluation couples the interconnect to a convolutional layer
processor built from vector dot-product units that double-buffer their inputs
and "perform perfect prefetch of data into the idle buffers" (§III-E) — which
is why Medusa's constant transposition latency is free.  On TPU this maps to a
K-streamed matmul: the grid walks K-tiles, the Pallas pipeline prefetches the
next operand tiles into the second VMEM slot while the MXU consumes the
current one, and a VMEM scratch accumulator carries partial sums in fp32.

The weight operand is consumed in the *banked, port-major* layout produced by
the Medusa read network, demonstrating the interconnect feeding the compute
units at full bandwidth.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _matmul_kernel(x_ref, w_ref, o_ref, acc_ref):
    @pl.when(pl.program_id(2) == 0)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(x_ref[...], w_ref[...],
                            preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == pl.num_programs(2) - 1)
    def _store():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("bm", "bn", "bk", "interpret"))
def stream_matmul(x: jax.Array, w: jax.Array, bm: int = 128, bn: int = 128,
                  bk: int = 128, interpret: bool = True) -> jax.Array:
    """``x [M, K] @ w [K, N]`` with K-streaming and fp32 accumulation.

    Block shapes are MXU-aligned (multiples of 128 on hardware); the K grid
    axis is "arbitrary" (sequential) so the accumulator carries across steps —
    the double-buffer/pipeline structure of the layer processor.
    """
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, (x.shape, w.shape)
    if m % bm or n % bn or k % bk:
        raise ValueError(f"shape ({m},{k})x({k2},{n}) not divisible by "
                         f"blocks ({bm},{bn},{bk})")
    grid = (m // bm, n // bn, k // bk)
    return pl.pallas_call(
        _matmul_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(x, w)
