"""Pure-jnp oracles for every Pallas kernel in this package.

Each function is the semantic ground truth the kernels are validated against
(tests sweep shapes/dtypes and ``assert_allclose`` kernel vs. oracle).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def transpose_ref(x: jax.Array) -> jax.Array:
    """Oracle for ``medusa_transpose`` kernels: swap the two leading axes of a
    ``[R, C, W]`` (payload-trailing) array."""
    return jnp.swapaxes(x, 0, 1)


def rotate_ref(x: jax.Array, amount: jax.Array | int) -> jax.Array:
    """Oracle for the barrel rotator: left rotation along axis 0."""
    return jnp.roll(x, -jnp.asarray(amount), axis=0)


def matmul_ref(x: jax.Array, w: jax.Array) -> jax.Array:
    """Oracle for the streaming matmul (fp32 accumulation)."""
    return jnp.dot(x.astype(jnp.float32), w.astype(jnp.float32)).astype(x.dtype)


def kv_layout_ref(kv: jax.Array) -> jax.Array:
    """Oracle for the KV-cache layout engine: line-major ``[T, H, D]`` →
    port-major ``[H, T, D]``."""
    return jnp.swapaxes(kv, 0, 1)
