"""Public, jit-friendly wrappers around the Pallas kernels.

These are what the framework calls.  Every op:

* validates/pads shapes to kernel tile requirements,
* dispatches to the Pallas kernel (``interpret=True`` on CPU — the kernel
  body is identical on TPU, where ``interpret=False`` is used),
* has a pure-jnp oracle in :mod:`repro.kernels.ref` which tests sweep against.

``use_kernels(False)`` (or the ``REPRO_NO_KERNELS`` env var) routes every op
to its oracle — used by the dry-run, where we want the XLA-native HLO of the
surrounding program rather than interpret-mode custom calls.
"""

from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.medusa_transpose import (burst_network_tiles,
                                            gather_burst_network_tiles,
                                            medusa_transpose_tiles,
                                            read_network_tiles,
                                            scatter_burst_network_tiles)
from repro.kernels.rotator import barrel_rotate_groups
from repro.kernels.stream_matmul import stream_matmul

_USE_KERNELS = os.environ.get("REPRO_NO_KERNELS", "") == ""


def use_kernels(enabled: bool) -> None:
    """Globally route ops to Pallas kernels (True) or jnp oracles (False)."""
    global _USE_KERNELS
    _USE_KERNELS = enabled


def kernels_enabled() -> bool:
    return _USE_KERNELS


def _pow2_floor(n: int) -> int:
    p = 1
    while p * 2 <= n:
        p *= 2
    return p


def transpose_rc(x: jax.Array, tile: int = 0) -> jax.Array:
    """Swap the two leading axes of ``x [R, C, W]`` → ``[C, R, W]`` via the
    Medusa exchange-network kernel (padding to square power-of-two tiles)."""
    if not _USE_KERNELS:
        return ref.transpose_ref(x)
    r, c, w = x.shape
    if tile == 0:
        tile = min(_pow2_floor(max(r, 1)), _pow2_floor(max(c, 1)), 64)
    pr, pc = (-r) % tile, (-c) % tile
    xp = jnp.pad(x, ((0, pr), (0, pc), (0, 0))) if (pr or pc) else x
    out = medusa_transpose_tiles(xp, tile=tile)
    return out[:c, :r]


def kv_line_to_port(kv: jax.Array) -> jax.Array:
    """KV-cache layout engine: line-major ``[T, H, D]`` (one timestep = one
    wide line across heads) → port-major ``[H, T, D]`` (one stream per head).
    This is the production read-network application (DESIGN.md §3.1)."""
    if not _USE_KERNELS:
        return ref.kv_layout_ref(kv)
    return transpose_rc(kv)


def interconnect_read(lines: jax.Array, n_ports: int) -> jax.Array:
    """Banked read network on tiles (kernel form of core.read_network_medusa)."""
    if not _USE_KERNELS:
        from repro.core.transpose import read_network_oracle
        return read_network_oracle(lines, n_ports)
    return read_network_tiles(lines, n_ports)


def burst_read(tile: jax.Array, n_ports: int) -> jax.Array:
    """Packed read burst ``[N, N, W]`` (N lines of N words) → banked
    ``[N, N, W]`` as ONE fused kernel launch (the burst scheduler's hot
    path; see :func:`repro.kernels.medusa_transpose.burst_network_tiles`)."""
    if not _USE_KERNELS:
        from repro.core.transpose import read_network_oracle
        return read_network_oracle(tile, n_ports)[0]
    return burst_network_tiles(tile, n_ports)


def burst_write(banked: jax.Array, n_ports: int) -> jax.Array:
    """Packed write burst: banked ``[N, N, W]`` → line tile ``[N, N, W]``
    as one fused kernel launch (the square exchange is an involution, so
    this is the same network run in the write direction)."""
    if not _USE_KERNELS:
        from repro.core.transpose import write_network_oracle
        return write_network_oracle(banked[None], n_ports)
    return burst_network_tiles(banked, n_ports)


def burst_gather_read(lines: jax.Array, idx: jax.Array,
                      n_ports: int) -> jax.Array:
    """Fused page-table gather + read network: pool lines ``[L, N, W]`` and
    frame indices ``idx [K]`` (sentinels ``>= L`` read as zero frames) →
    banked ``[K//N, N, N, W]`` of exactly the addressed frames, one launch
    with the indices as a scalar-prefetched operand (vLLM paged-attention
    style — the network moves live frames, not the pool)."""
    if not _USE_KERNELS:
        from repro.core.transpose import read_network_oracle
        taken = jnp.take(lines, idx, axis=0, mode="fill", fill_value=0)
        return read_network_oracle(taken, n_ports)
    return gather_burst_network_tiles(lines, idx, n_ports)


def burst_scatter_write(banked: jax.Array, idx: jax.Array, into: jax.Array,
                        n_ports: int) -> jax.Array:
    """Fused write network + page-table scatter: banked ``[G, N, N, W]`` →
    frames landed at rows ``idx [G*N]`` of the pool stream ``into [L, N, W]``
    (sentinels drop; untouched rows keep their frames without moving), one
    input-output-aliased launch."""
    if not _USE_KERNELS:
        from repro.core.transpose import write_network_oracle
        lines = write_network_oracle(banked, n_ports)
        return into.at[idx].set(lines, mode="drop")
    return scatter_burst_network_tiles(banked, idx, into, n_ports)


def rotate_groups(x: jax.Array, amounts: jax.Array) -> jax.Array:
    """Barrel-rotate each ``x[g] [N, W]`` left by ``amounts[g]``."""
    if not _USE_KERNELS:
        return jax.vmap(ref.rotate_ref)(x, amounts)
    return barrel_rotate_groups(x, amounts)


def matmul(x: jax.Array, w: jax.Array, bm: int = 0, bn: int = 0,
           bk: int = 0) -> jax.Array:
    """Streaming double-buffered matmul; falls back to the oracle when shapes
    do not tile cleanly (kernels are for the aligned hot path)."""
    if not _USE_KERNELS:
        return ref.matmul_ref(x, w)
    m, k = x.shape
    _, n = w.shape
    bm = bm or min(128, _pow2_floor(m))
    bn = bn or min(128, _pow2_floor(n))
    bk = bk or min(128, _pow2_floor(k))
    if m % bm or n % bn or k % bk:
        return ref.matmul_ref(x, w)
    return stream_matmul(x, w, bm=bm, bn=bn, bk=bk)
