"""Logical-axis sharding: the single place mesh layout decisions live.

Model code annotates activations/params with *logical* axis names
(``shard(x, "batch", "seq", "d_model")``); a :class:`Sharder` maps logical
names to mesh axes through a rules table and applies
``jax.lax.with_sharding_constraint``.  With no sharder installed (CPU smoke
tests) the calls are no-ops, so the same model code runs everywhere.

Two built-in profiles:

* ``tp_heads`` — classic DP x TP: batch over (pod, data); heads / d_ff /
  vocab / experts over model.  Default for every arch.
* ``sp_seq``   — sequence parallelism: batch over (pod, data), sequence over
  model for activations (used when an arch's head count cannot split the
  model axis, e.g. gemma3-4b with 8 heads on a 16-way axis, and for
  long-context cells where the KV cache must shard over chips).

A rule maps a logical name to a mesh axis (or tuple of axes).  Constraints
silently skip non-divisible dims (XLA would pad; we prefer explicitness: the
dim stays unsharded and the dry-run memory report shows it).
"""

from __future__ import annotations

import contextlib
import dataclasses
import threading
from typing import Optional, Sequence, Union

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Axis = Union[None, str, tuple]

LOGICAL_RULES_TP = {
    "batch": ("pod", "data"),
    "seq": None,
    "kv_seq": None,
    "heads": "model",
    "kv_heads": "model",
    "head_dim": None,
    "d_model": None,
    "d_ff": "model",
    "vocab": "model",
    "experts": "model",
    "expert_cap": None,
    "inner": "model",            # mamba d_inner / rg-lru width
    "state": None,
    "conv": None,
    "frames": None,
    "patches": None,
}

LOGICAL_RULES_SP = dict(LOGICAL_RULES_TP, **{
    "heads": None,
    "kv_heads": None,
    "seq": "model",
    "kv_seq": "model",
})

# MoE archs whose expert count cannot split the model axis (granite: 40e on a
# 16-way axis): shard the capacity dim instead and sequence-parallel attention.
LOGICAL_RULES_MOE_CAP = dict(LOGICAL_RULES_SP, **{
    "experts": None,
    "expert_cap": "model",
})

# 2-D expert parallelism: experts over model AND token capacity over data —
# dispatch buffers fully sharded (beyond-paper §Perf iteration).
LOGICAL_RULES_EP_2D = dict(LOGICAL_RULES_SP, **{
    "experts": "model",
    "expert_cap": ("pod", "data"),
})

def rules_for(profile: str) -> dict:
    if profile == "tp_heads":
        return dict(LOGICAL_RULES_TP)
    if profile == "sp_seq":
        return dict(LOGICAL_RULES_SP)
    if profile == "moe_cap":
        return dict(LOGICAL_RULES_MOE_CAP)
    if profile == "ep_2d":
        return dict(LOGICAL_RULES_EP_2D)
    raise ValueError(f"unknown sharding profile {profile!r}")


@dataclasses.dataclass
class Sharder:
    mesh: Mesh
    rules: dict

    def spec(self, *logical: Optional[str]) -> P:
        used: set = set()
        axes = []
        for name in logical:
            ax = self.rules.get(name) if name else None
            # an axis may appear at most once in a PartitionSpec
            if ax is None:
                axes.append(None)
                continue
            flat = (ax,) if isinstance(ax, str) else tuple(ax)
            flat = tuple(a for a in flat if a not in used and a in self.mesh.axis_names)
            used.update(flat)
            axes.append(flat if len(flat) > 1 else (flat[0] if flat else None))
        while axes and axes[-1] is None:
            axes.pop()
        return P(*axes)

    def _divisible(self, shape, spec: P) -> bool:
        sizes = dict(zip(self.mesh.axis_names, self.mesh.devices.shape))
        for dim, ax in zip(shape, tuple(spec) + (None,) * (len(shape) - len(spec))):
            if ax is None:
                continue
            flat = (ax,) if isinstance(ax, str) else ax
            total = int(np.prod([sizes[a] for a in flat]))
            if dim % total:
                return False
        return True

    def safe_spec(self, shape, logical) -> P:
        """spec() that silently drops axes a dim cannot divide.

        When a rule maps to an axis tuple (e.g. batch → (pod, data)) and only
        a prefix divides, the divisible prefix is kept — so batch=256 on a
        (pod=2, data=16) mesh shards 32-way, while batch=2 still shards over
        pod alone rather than falling back to replication.
        """
        sizes = dict(zip(self.mesh.axis_names, self.mesh.devices.shape))
        kept = []
        for dim, name in zip(shape, logical):
            ax = self.rules.get(name) if name else None
            if ax is not None:
                flat = (ax,) if isinstance(ax, str) else tuple(ax)
                flat = tuple(a for a in flat if a in self.mesh.axis_names)
                while flat:
                    total = int(np.prod([sizes[a] for a in flat]))
                    if dim % total == 0 and total > 1:
                        break
                    flat = flat[:-1]
                ax = flat if flat else None
            kept.append(ax)
        return self._spec_from_axes(kept)

    def shard(self, x: jax.Array, *logical: Optional[str]) -> jax.Array:
        if len(logical) != x.ndim:
            raise ValueError(f"rank mismatch: {x.shape} vs {logical}")
        spec = self.safe_spec(x.shape, logical)
        return jax.lax.with_sharding_constraint(x, NamedSharding(self.mesh, spec))

    def _spec_from_axes(self, axes) -> P:
        used: set = set()
        out = []
        for ax in axes:
            if ax is None:
                out.append(None)
                continue
            flat = (ax,) if isinstance(ax, str) else tuple(ax)
            flat = tuple(a for a in flat
                         if a not in used and a in self.mesh.axis_names)
            used.update(flat)
            out.append(flat if len(flat) > 1 else (flat[0] if flat else None))
        while out and out[-1] is None:
            out.pop()
        return P(*out)

    def named_sharding(self, *logical: Optional[str]) -> NamedSharding:
        return NamedSharding(self.mesh, self.spec(*logical))


_STATE = threading.local()


def set_sharder(s: Optional[Sharder]) -> None:
    _STATE.sharder = s


def current_sharder() -> Optional[Sharder]:
    return getattr(_STATE, "sharder", None)


@contextlib.contextmanager
def use_sharder(s: Optional[Sharder]):
    prev = current_sharder()
    set_sharder(s)
    try:
        yield
    finally:
        set_sharder(prev)


def no_sharding():
    return use_sharder(None)


def shard(x: jax.Array, *logical: Optional[str]) -> jax.Array:
    """Annotate ``x`` with logical axes; no-op when no sharder installed."""
    s = current_sharder()
    if s is None:
        return x
    return s.shard(x, *logical)
