"""Parallelism substrate: logical sharding rules, mesh helpers, collectives."""

from repro.parallel.sharding import (Sharder, current_sharder, set_sharder,
                                     no_sharding, LOGICAL_RULES_TP,
                                     LOGICAL_RULES_SP, rules_for)

__all__ = ["Sharder", "current_sharder", "set_sharder", "no_sharding",
           "LOGICAL_RULES_TP", "LOGICAL_RULES_SP", "rules_for"]
