"""Pipeline parallelism: a functional GPipe/1F1B-style microbatch pipeline.

Stages live on a ``pipe`` mesh axis (shard_map); activations move stage to
stage with ``lax.ppermute`` — neighbour-aligned on the ICI ring, the same
rotation primitive as the Medusa collective schedule.  The schedule runs
``M + P - 1`` ticks for M microbatches over P stages (bubble fraction
``(P-1)/(M+P-1)``); autodiff through the scan-of-ppermutes yields the
reversed pipeline for the backward pass.

The assigned production meshes are 2-axis (data, model) — layer-scan + ZeRO
covers them better (DESIGN.md §8) — but the substrate supports a third
``pipe`` axis; ``tests/test_pipeline.py`` validates numerics on a host mesh.
"""

from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
from jax import lax

from repro.parallel.collectives import axis_size


def pipeline_forward(stage_fn: Callable, stage_params, microbatches,
                     axis_name: str = "pipe"):
    """Run ``microbatches [M, mb, ...]`` through P pipelined stages.

    ``stage_fn(stage_params, x) -> y`` is THIS stage's compute (stage_params
    are already sharded over ``axis_name`` by the enclosing shard_map).
    Returns ``[M, mb, ...]`` outputs of the final stage.  Microbatch ``m``
    occupies stage ``s`` at tick ``m + s`` — the diagonal schedule again.
    """
    p = axis_size(axis_name)
    idx = lax.axis_index(axis_name)
    m = microbatches.shape[0]
    ticks = m + p - 1

    def tick(h, t):
        inject = microbatches[jnp.clip(t, 0, m - 1)]
        h = jnp.where((idx == 0) & (t < m), inject, h)
        h = stage_fn(stage_params, h)
        # only the last stage emits; psum replicates it to every rank
        emit = lax.psum(jnp.where(idx == p - 1, h, jnp.zeros_like(h)),
                        axis_name)
        # shift to the next stage (no wraparound: stage 0 re-injects)
        h_next = lax.ppermute(h, axis_name,
                              [(i, i + 1) for i in range(p - 1)])
        return h_next, emit

    _, emits = lax.scan(tick, jnp.zeros_like(microbatches[0]),
                        jnp.arange(ticks))
    # microbatch m finishes at tick m + p - 1 on the last stage
    return emits[p - 1:]


def pipeline_loss(stage_fn: Callable, loss_fn: Callable, stage_params,
                  microbatches, targets, axis_name: str = "pipe"):
    """Mean loss over microbatches; differentiable → pipelined backward."""
    outs = pipeline_forward(stage_fn, stage_params, microbatches, axis_name)
    losses = jax.vmap(loss_fn)(outs, targets)
    return losses.mean()


def bubble_fraction(num_microbatches: int, num_stages: int) -> float:
    """Pipeline bubble overhead of the schedule (EXPERIMENTS.md §Perf)."""
    return (num_stages - 1) / (num_microbatches + num_stages - 1)
