"""Medusa collective schedule: all-to-all as N-1 ring rotations.

The paper replaces a crossbar with a rotation unit because bandwidth is
evenly, statically partitioned.  The inter-chip analogue: an all-to-all whose
per-peer payload is uniform (MoE dispatch with fixed capacity — even static
partition by construction) can run as ``N-1`` steps of ``lax.ppermute`` with
rotation ``s = 1..N-1``; step ``s`` moves the "diagonal" blocks ``(d → d+s)``,
exactly the §III-A diagonal schedule.  On a physical ICI ring/torus each step
is a neighbour-aligned permute that XLA can overlap with expert compute,
whereas the monolithic ``all_to_all`` "crossbar" serialises against it.

Also here: ``compressed_psum`` (int8 gradient all-reduce) and a plain ring
all-gather used by the weight-streaming demo.
"""

from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
from jax import lax

from repro.runtime.compression import int8_quantize, int8_dequantize


def axis_size(axis_name: str) -> int:
    """``lax.axis_size`` where it exists; ``psum(1, axis)`` (also static
    under shard_map/pmap tracing) on older jax."""
    if hasattr(lax, "axis_size"):
        return lax.axis_size(axis_name)
    return lax.psum(1, axis_name)


def ring_all_to_all(x: jax.Array, axis_name: str) -> jax.Array:
    """All-to-all of ``x [N, ...]`` (block j destined to rank j) using N-1
    rotation steps.  Equivalent to ``lax.all_to_all`` with uniform blocks.

    Must run inside ``shard_map``/``pmap`` with ``axis_name`` bound.
    """
    n = axis_size(axis_name)
    idx = lax.axis_index(axis_name)
    out = jnp.zeros_like(x)
    # my own block stays put
    own = lax.dynamic_index_in_dim(x, idx, axis=0, keepdims=True)
    out = lax.dynamic_update_index_in_dim(out, own, idx, axis=0)
    for s in range(1, n):
        # step s: every rank sends the block destined for rank (idx+s)%N
        send = lax.dynamic_index_in_dim(x, (idx + s) % n, axis=0,
                                        keepdims=True)
        perm = [(i, (i + s) % n) for i in range(n)]
        recv = lax.ppermute(send, axis_name, perm)
        out = lax.dynamic_update_index_in_dim(out, recv, (idx - s) % n, axis=0)
    return out


def xla_all_to_all(x: jax.Array, axis_name: str) -> jax.Array:
    """The "crossbar": XLA's monolithic all-to-all on the same layout."""
    return lax.all_to_all(x, axis_name, split_axis=0, concat_axis=0,
                          tiled=True).reshape(x.shape)


def ring_all_gather(x: jax.Array, axis_name: str) -> jax.Array:
    """All-gather as N-1 neighbour rotations (overlap-friendly weight
    streaming: each step's block can feed compute while the next streams)."""
    n = axis_size(axis_name)
    blocks = [x]
    cur = x
    perm = [(i, (i + 1) % n) for i in range(n)]
    for _ in range(n - 1):
        cur = lax.ppermute(cur, axis_name, perm)
        blocks.append(cur)
    idx = lax.axis_index(axis_name)
    stacked = jnp.stack(blocks)                    # [N, ...] rotated order
    # stacked[s] is the block of rank (idx - s) % n; restore rank order
    ranks = (idx - jnp.arange(n)) % n
    out = jnp.zeros_like(stacked)
    out = out.at[ranks].set(stacked)
    return out.reshape((n * x.shape[0],) + x.shape[1:])


def compressed_psum(g: jax.Array, axis_name: str) -> jax.Array:
    """int8-compressed gradient all-reduce: quantise locally, sum int32,
    dequantise with a shared (max) scale — 8x DP all-reduce bytes."""
    scale = lax.pmax(jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / 127.0,
                     axis_name)
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    total = lax.psum(q.astype(jnp.int32), axis_name)
    return total.astype(jnp.float32) * scale


def dp_grad_mean(grads, axis_name: str, compression: str = "none"):
    """Data-parallel gradient mean with optional compression (shard_map DP)."""
    n = axis_size(axis_name)
    if compression == "int8":
        return jax.tree.map(lambda g: compressed_psum(g, axis_name) / n, grads)
    return jax.tree.map(lambda g: lax.pmean(g, axis_name), grads)
