"""Fault tolerance: restart-on-failure, straggler detection, elastic re-mesh.

At 1000+ nodes, node loss is routine.  The runner wraps the training loop so
that *any* step failure (device loss, injected fault, numerical blow-up
configured as fatal) triggers restore-from-latest-checkpoint and continuation
— the data pipeline is stateless-resumable (`batch_at(step)`), so recovery is
exact.  Elastic re-mesh re-places a host checkpoint onto a different mesh via
`restore_checkpoint(shardings=new)` — used when a pod returns with fewer
slices.  The straggler detector flags steps slower than ``threshold x`` the
EMA; on real clusters the hook triggers slice replacement, here it logs and
counts (unit-tested behaviour).
"""

from __future__ import annotations

import dataclasses
import logging
import time
from typing import Callable, Optional

from repro.checkpoint import CheckpointManager, latest_step, restore_checkpoint

log = logging.getLogger("repro.runtime")


@dataclasses.dataclass
class StragglerDetector:
    threshold: float = 2.0
    decay: float = 0.9
    ema: Optional[float] = None
    flagged: int = 0

    def observe(self, dt: float) -> bool:
        if self.ema is None:
            self.ema = dt
            return False
        is_straggler = dt > self.threshold * self.ema
        if is_straggler:
            self.flagged += 1
            log.warning("straggler step: %.3fs vs EMA %.3fs", dt, self.ema)
        else:
            # stragglers do not poison the EMA
            self.ema = self.decay * self.ema + (1 - self.decay) * dt
        return is_straggler


class FaultInjector:
    """Deterministic fault schedule for tests/examples.

    Three fault classes, each fired at most once per scheduled occurrence:

    * ``fail_at`` — raise mid-step (the training runner restores the latest
      checkpoint; the serving engine rolls back to its pre-step snapshot
      and replays the step — ``SchedulerStats.faults_recovered``);
    * ``exhaust_pool_at`` — the serving engine's admission sees zero pool
      headroom at these steps (a transient allocation failure: admission
      backs off and retries next step);
    * ``corrupt_swap`` — the n-th ``swap/*`` burst (0-indexed ordinal over
      swap-out and swap-in transfers) is corrupted in flight on its first
      attempt; the end-to-end parity word catches it and the transfer is
      retried once (``SchedulerStats.bursts_retried``).
    """

    @classmethod
    def seeded(cls, seed: int, horizon: int, p_fail: float = 0.01,
               p_exhaust: float = 0.02, n_corrupt: int = 1
               ) -> "FaultInjector":
        """A deterministic fault schedule drawn from one seed — the soak
        harness's injector: each step in ``[1, horizon)`` independently
        fails mid-step with ``p_fail`` and sees an exhausted pool with
        ``p_exhaust``, and the first ``n_corrupt`` swap bursts are
        corrupted.  Same seed → same schedule, so a soak run replays
        bit-exactly.  (Step 0 is excluded: nothing is live yet, so a fault
        there exercises no recovery path.)"""
        import numpy as np
        rng = np.random.default_rng(seed)
        draws = rng.random((max(horizon, 1), 2))
        fail = tuple(s for s in range(1, horizon) if draws[s, 0] < p_fail)
        exhaust = tuple(s for s in range(1, horizon)
                        if draws[s, 1] < p_exhaust)
        return cls(fail_at=fail, exhaust_pool_at=exhaust,
                   corrupt_swap=tuple(range(n_corrupt)))

    def __init__(self, fail_at: tuple = (), exhaust_pool_at: tuple = (),
                 corrupt_swap: tuple = ()):
        self.fail_at = set(fail_at)
        self.fired = set()
        self.exhaust_pool_at = set(exhaust_pool_at)
        self.exhaust_fired = set()
        self.corrupt_swap_at = set(corrupt_swap)
        self._swap_ordinal = 0
        self.corrupted = 0

    def check(self, step: int):
        if step in self.fail_at and step not in self.fired:
            self.fired.add(step)
            raise RuntimeError(f"injected node failure at step {step}")

    def pool_exhausted(self, step: int) -> bool:
        """Whether admission at ``step`` should see an exhausted pool."""
        if step in self.exhaust_pool_at and step not in self.exhaust_fired:
            self.exhaust_fired.add(step)
            return True
        return False

    def corrupt_swap_burst(self, attempt: int) -> bool:
        """Consulted once per swap-transfer attempt.  The transfer ordinal
        advances on the first attempt only, so a retry of a corrupted
        transfer sees a clean channel."""
        if attempt:
            return False
        k = self._swap_ordinal
        self._swap_ordinal += 1
        if k in self.corrupt_swap_at:
            self.corrupted += 1
            return True
        return False


class TrainingRunner:
    """Checkpoint/restart training driver.

    ``step_fn(state, batch) -> (state, metrics)`` must be a pure (jitted)
    function; ``state`` is any pytree (params + opt state).  On failure the
    runner restores the latest checkpoint and replays from that step.
    """

    def __init__(self, step_fn: Callable, data, ckpt: CheckpointManager,
                 straggler: Optional[StragglerDetector] = None,
                 fault_injector: Optional[FaultInjector] = None,
                 max_restarts: int = 10):
        self.step_fn = step_fn
        self.data = data
        self.ckpt = ckpt
        self.straggler = straggler or StragglerDetector()
        self.fault_injector = fault_injector
        self.max_restarts = max_restarts
        self.restarts = 0

    def run(self, state, start_step: int, num_steps: int,
            shardings=None, on_metrics: Optional[Callable] = None):
        step = start_step
        end = start_step + num_steps
        while step < end:
            try:
                while step < end:
                    if self.fault_injector is not None:
                        self.fault_injector.check(step)
                    t0 = time.monotonic()
                    batch = self.data.batch_at(step)
                    state, metrics = self.step_fn(state, batch)
                    self.straggler.observe(time.monotonic() - t0)
                    step += 1
                    self.ckpt.maybe_save(step, state, {"data_step": step})
                    if on_metrics is not None:
                        on_metrics(step, metrics)
            except (RuntimeError, OSError) as e:      # node failure class
                self.restarts += 1
                if self.restarts > self.max_restarts:
                    raise
                log.warning("step %d failed (%s); restoring latest checkpoint",
                            step, e)
                last = latest_step(self.ckpt.directory)
                if last is None:
                    # no checkpoint yet: restart from the initial state
                    step = start_step
                    continue
                state, extra = restore_checkpoint(
                    self.ckpt.directory, last, state, shardings)
                step = extra.get("data_step", last)
        return state, step
