"""Gradient compression: int8 quantisation with error feedback.

Used by the explicitly-collective DP path (`shard_map` data parallelism): each
rank quantises its local gradient to int8 + one fp32 scale per tensor before
the all-reduce, then dequantises; the quantisation residual is carried in an
error-feedback buffer and added to the next step's gradient, preserving
convergence (1-bit-Adam-style).  8x reduction in DP all-reduce bytes — a
distributed-optimisation trick orthogonal to the Medusa interconnect work but
required at 1000+ node scale.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


def int8_quantize(g: jax.Array):
    scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def int8_dequantize(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class ErrorFeedback:
    buf: Any

    @staticmethod
    def init(grads):
        return ErrorFeedback(jax.tree.map(
            lambda g: jnp.zeros(g.shape, jnp.float32), grads))


def compress_grads(grads, ef: ErrorFeedback):
    """Quantise grads (+error feedback).  Returns (quantised pytree of
    (q, scale), new ErrorFeedback).  Residual = g - dequant(quant(g))."""
    def one(g, e):
        g32 = g.astype(jnp.float32) + e
        q, scale = int8_quantize(g32)
        resid = g32 - int8_dequantize(q, scale)
        return (q, scale), resid

    pairs = jax.tree.map(one, grads, ef.buf,
                         is_leaf=lambda x: isinstance(x, jax.Array))
    qtree = jax.tree.map(lambda p: p[0], pairs,
                         is_leaf=lambda x: isinstance(x, tuple))
    resid = jax.tree.map(lambda p: p[1], pairs,
                         is_leaf=lambda x: isinstance(x, tuple))
    return qtree, ErrorFeedback(resid)


def decompress_grads(qtree):
    return jax.tree.map(lambda p: int8_dequantize(*p), qtree,
                        is_leaf=lambda x: isinstance(x, tuple))
