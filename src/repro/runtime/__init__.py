from repro.runtime.fault_tolerance import (TrainingRunner, StragglerDetector,
                                           FaultInjector)
from repro.runtime.compression import (int8_quantize, int8_dequantize,
                                       ErrorFeedback, compress_grads)

__all__ = ["TrainingRunner", "StragglerDetector", "FaultInjector",
           "int8_quantize", "int8_dequantize", "ErrorFeedback",
           "compress_grads"]
