"""Unified model API: family dispatch + step builders.

Every architecture exposes the same four entry points regardless of family:

* ``init_params(cfg, key)``
* ``loss_fn(params, batch, cfg)``                  (training)
* ``prefill_fn(params, batch, cfg, t_max)``        (serving, installs caches)
* ``decode_fn(params, token, caches, pos, cfg)``   (serving, one step)

``batch`` carries modality stubs where assigned: ``patch_embeds`` (VLM) and
``frames`` (audio).  The launch layer (`repro.launch`) wraps these into
pjit-ed ``train_step`` / ``serve_step`` with sharding and optimizer logic.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import common as cm
from repro.models import lm, whisper
from repro.models.moe import aux_load_balance_loss


def init_params(cfg: ModelConfig, key) -> dict:
    if cfg.family == "audio":
        return whisper.init_params(cfg, key)
    return lm.init_params(cfg, key)


def _kv_chunk_for(seq: int) -> int:
    return 1024 if seq > 2048 else 0


def loss_fn(params, batch: dict, cfg: ModelConfig) -> jax.Array:
    tokens = batch["tokens"]
    targets = batch["targets"]
    kv_chunk = _kv_chunk_for(tokens.shape[1])
    if cfg.family == "audio":
        logits = whisper.forward(params, tokens, batch["frames"], cfg,
                                 kv_chunk=kv_chunk)
    else:
        logits = lm.forward(params, tokens, cfg,
                            patch_embeds=batch.get("patch_embeds"),
                            kv_chunk=kv_chunk)
        if cfg.n_patches and "patch_embeds" in batch:
            logits = logits[:, cfg.n_patches:]   # loss over text positions
    loss = cm.softmax_xent(logits, targets, cfg.vocab_size)
    if cfg.moe is not None:
        # router balance on the embedding output of the first tokens (cheap
        # proxy shared across layers; per-layer aux is summed during forward
        # in full-fidelity mode — see DESIGN.md §8)
        x = cm.embed_apply(params["embed"], tokens)
        first = (params["unit"][0]["ffn"] if params.get("unit")
                 else params["tail"][0]["ffn"])
        router0 = jax.tree.map(lambda a: a[0], first)
        loss = loss + 0.01 * aux_load_balance_loss(router0, x, cfg)
    return loss


def prefill_fn(params, batch: dict, cfg: ModelConfig, t_max: int):
    tokens = batch["tokens"]
    kv_chunk = _kv_chunk_for(tokens.shape[1])
    if cfg.family == "audio":
        return whisper.prefill(params, tokens, batch["frames"], cfg, t_max)
    return lm.prefill(params, tokens, cfg, t_max,
                      patch_embeds=batch.get("patch_embeds"),
                      kv_chunk=kv_chunk)


def init_cache(cfg: ModelConfig, batch: int, t_max: int,
               pool_pages: int = 0, page_size: int = 0):
    """Decode-cache pytree; ``pool_pages > 0`` backs the full-attention
    leaves with a shared physical page pool (decoder-only families — see
    :func:`repro.models.lm.init_cache`)."""
    if cfg.family == "audio":
        assert not pool_pages, "paged pool covers decoder-only families"
        return whisper.init_cache(cfg, batch, t_max)
    return lm.init_cache(cfg, batch, t_max, pool_pages=pool_pages,
                         page_size=page_size)


def decode_fn(params, token, caches, pos, cfg: ModelConfig, sched=None,
              page_table=None, page_size: int = 0, t_depth: int = 0,
              live_plan=None, shard_plans=None, draft: bool = False):
    """One decode step.  ``sched`` (a :class:`repro.fabric.BurstScheduler`)
    routes the step's KV banking — and ``serve_fsdp`` weight streaming —
    through one read and one write network burst (decoder-only families).
    ``page_table`` (+ static ``page_size``/``t_depth``) switches the
    full-attention leaves to the shared physical page pool with
    gather-based decode (``FabricConfig.paged_pool``); ``live_plan`` (the
    operands from :func:`repro.models.common.page_live_plan`) fuses the
    pool gather into the burst contract so the networks move only live
    frames (``FabricConfig.fused_gather``); ``shard_plans`` (``{reps:
    (fetch, place)}`` from :func:`repro.fabric.shard_plan`) lowers those
    sparse bursts over the pool-sharded device mesh
    (``FabricConfig.pool_shards``).  ``draft`` appends the Medusa draft
    heads' proposals to the step logits (``[B, 1+k, V]``, row 0 the real
    unembedding — see :func:`repro.models.lm._emit_logits`)."""
    if cfg.family == "audio":
        assert page_table is None, "paged pool covers decoder-only families"
        assert not draft, "draft heads cover decoder-only families"
        return whisper.decode_step(params, token, caches, pos, cfg)
    return lm.decode_step(params, token, caches, pos, cfg, sched=sched,
                          page_table=page_table, page_size=page_size,
                          t_depth=t_depth, live_plan=live_plan,
                          shard_plans=shard_plans, draft=draft)


def greedy_generate(params, prompt, cfg: ModelConfig, steps: int,
                    t_max: int, extra: Optional[dict] = None):
    """Greedy decoding loop (used by examples and integration tests)."""
    batch = {"tokens": prompt, **(extra or {})}
    logits, caches = prefill_fn(params, batch, cfg, t_max)
    tok = jnp.argmax(logits[:, -1], axis=-1)[:, None]
    pos0 = prompt.shape[1] + (cfg.n_patches or 0)
    out = [tok]

    def body(i, state):
        tok, caches, acc = state
        logits, caches = decode_fn(params, tok, caches, pos0 + i, cfg)
        tok = jnp.argmax(logits[:, -1], axis=-1)[:, None]
        acc = jax.lax.dynamic_update_slice_in_dim(acc, tok, i, axis=1)
        return tok, caches, acc

    acc = jnp.zeros((prompt.shape[0], steps), dtype=prompt.dtype)
    tok, caches, acc = jax.lax.fori_loop(
        0, steps, lambda i, s: body(i, s), (tok, caches, acc))
    return acc
