"""Shared model components: norms, RoPE, attention (train/prefill/decode),
MLP variants, embeddings, losses, initialisation.

All functions are pure; parameters are plain dicts of arrays.  Activation
sharding is annotated through :func:`repro.parallel.sharding.shard` with
logical axis names, so the same code runs unsharded on CPU and pjit-sharded
on the production mesh.
"""

from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.fabric.fabric import Fabric, pm_to_banked
from repro.fabric.scheduler import FRAME_SENTINEL as _SENTINEL
from repro.parallel.sharding import shard


# ----------------------------------------------------------------------------
# init
# ----------------------------------------------------------------------------

def trunc_normal(key, shape, dtype, scale: float) -> jax.Array:
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)
            * scale).astype(dtype)


def dense_init(key, d_in, d_out, dtype) -> jax.Array:
    return trunc_normal(key, (d_in, d_out), dtype, 1.0 / math.sqrt(d_in))


def pad_vocab(v: int, multiple: int = 128) -> int:
    return -(-v // multiple) * multiple


# ----------------------------------------------------------------------------
# norms
# ----------------------------------------------------------------------------

def rms_norm(x: jax.Array, w: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * (1.0 + w.astype(jnp.float32))).astype(dt)


def layer_norm(x: jax.Array, w: jax.Array, b: jax.Array,
               eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    x = (x - mu) * jax.lax.rsqrt(var + eps)
    return (x * w.astype(jnp.float32) + b.astype(jnp.float32)).astype(dt)


def apply_norm(x, p, kind: str):
    if kind == "rms":
        return rms_norm(x, p["scale"])
    return layer_norm(x, p["scale"], p["bias"])


def init_norm(key, d, dtype, kind: str):
    del key
    if kind == "rms":
        return {"scale": jnp.zeros((d,), dtype)}
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


# ----------------------------------------------------------------------------
# RoPE
# ----------------------------------------------------------------------------

def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Rotary embedding on ``x [..., S, H, D]`` with ``positions [..., S]``."""
    d = x.shape[-1]
    half = d // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freq        # [..., S, half]
    ang = ang[..., None, :]                                      # [..., S, 1, half]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., :half].astype(jnp.float32), x[..., half:].astype(jnp.float32)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ----------------------------------------------------------------------------
# attention
# ----------------------------------------------------------------------------

def _gqa_scores(q, k):
    """q [B,Sq,Hkv,G,D] x k [B,Sk,Hkv,D] → [B,Hkv,G,Sq,Sk] (no KV repeat)."""
    return jnp.einsum("bqhgd,bkhd->bhgqk", q, k,
                      preferred_element_type=jnp.float32)


def attention(q: jax.Array, k: jax.Array, v: jax.Array,
              q_positions: jax.Array, kv_positions: jax.Array,
              causal: bool = True, window: int = 0,
              kv_chunk: int = 0) -> jax.Array:
    """Memory-efficient multi-query attention.

    ``q [B, Sq, H, D]``, ``k/v [B, Sk, Hkv, D]``; grouped heads are folded so
    KV is never materialised H/Hkv times.  When ``kv_chunk > 0`` the KV axis
    is processed in chunks with an online-softmax (flash-style) scan — the
    form used for the 32k prefill and all long-context cells, bounding live
    intermediates to one [B, H, Sq, kv_chunk] tile per step.
    ``window > 0`` restricts attention to the last ``window`` positions
    (sliding-window / local layers).
    """
    b, sq, h, d = q.shape
    _, sk, hkv, _ = k.shape
    g = h // hkv
    qg = q.reshape(b, sq, hkv, g, d) * (d ** -0.5)
    scale_mask = lambda s, qp, kp: _mask_scores(s, qp, kp, causal, window)

    if not kv_chunk or kv_chunk >= sk:
        scores = _gqa_scores(qg, k)                              # f32
        scores = scale_mask(scores, q_positions, kv_positions)
        probs = jax.nn.softmax(scores, axis=-1)
        out = jnp.einsum("bhgqk,bkhd->bqhgd", probs.astype(v.dtype), v)
        return out.reshape(b, sq, h, d)

    n_chunks = sk // kv_chunk
    k_c = k.reshape(b, n_chunks, kv_chunk, hkv, d)
    v_c = v.reshape(b, n_chunks, kv_chunk, hkv, d)
    kp_c = kv_positions.reshape(n_chunks, kv_chunk)

    def step(carry, inp):
        m_prev, l_prev, acc = carry
        kc, vc, kpc = inp
        s = _gqa_scores(qg, kc)                                  # [B,hkv,g,Sq,C]
        s = scale_mask(s, q_positions, kpc)
        m_cur = jnp.maximum(m_prev, s.max(axis=-1))
        alpha = jnp.exp(m_prev - m_cur)
        p = jnp.exp(s - m_cur[..., None])
        l_cur = l_prev * alpha + p.sum(axis=-1)
        pv = jnp.einsum("bhgqk,bkhd->bhgqd", p.astype(vc.dtype), vc)
        acc = acc * alpha[..., None].astype(acc.dtype) + pv
        return (m_cur, l_cur, acc), None

    m0 = jnp.full((b, hkv, g, sq), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((b, hkv, g, sq), jnp.float32)
    acc0 = jnp.zeros((b, hkv, g, sq, d), v.dtype)
    (m, l, acc), _ = jax.lax.scan(
        step, (m0, l0, acc0),
        (jnp.moveaxis(k_c, 1, 0), jnp.moveaxis(v_c, 1, 0), kp_c))
    out = acc / jnp.maximum(l, 1e-30)[..., None].astype(acc.dtype)
    return jnp.moveaxis(out, -2, 1).reshape(b, sq, h, d)


def _mask_scores(scores, q_pos, k_pos, causal, window):
    """Apply causal / sliding-window masking in position space."""
    qp = q_pos[..., :, None] if q_pos.ndim == 1 else q_pos[:, None, None, :, None]
    kp = k_pos[..., None, :] if k_pos.ndim == 1 else k_pos[:, None, None, None, :]
    neg = jnp.float32(-1e30)
    if causal:
        scores = jnp.where(qp >= kp, scores, neg)
    if window:
        scores = jnp.where(qp - kp < window, scores, neg)
    return scores


def attention_block_params(key, cfg, dtype) -> dict:
    hd = cfg.resolved_head_dim
    ks = jax.random.split(key, 4)
    return {
        "wq": dense_init(ks[0], cfg.d_model, cfg.n_heads * hd, dtype),
        "wk": dense_init(ks[1], cfg.d_model, cfg.n_kv_heads * hd, dtype),
        "wv": dense_init(ks[2], cfg.d_model, cfg.n_kv_heads * hd, dtype),
        "wo": dense_init(ks[3], cfg.n_heads * hd, cfg.d_model, dtype),
    }


def sinusoidal_positions(seq: int, d: int) -> jax.Array:
    """Standard sinusoidal absolute position embedding [seq, d] (whisper)."""
    pos = jnp.arange(seq, dtype=jnp.float32)[:, None]
    dim = jnp.arange(d // 2, dtype=jnp.float32)[None, :]
    ang = pos / jnp.power(10_000.0, 2.0 * dim / d)
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def sinusoidal_at(pos: jax.Array, d: int) -> jax.Array:
    """Sinusoidal embedding for a single (traced) position → [d]."""
    dim = jnp.arange(d // 2, dtype=jnp.float32)
    ang = pos.astype(jnp.float32) / jnp.power(10_000.0, 2.0 * dim / d)
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)])


def _qkv_project(p, x, cfg, *, positions, layer_kind: str,
                 apply_rope: bool = True):
    """Shared attention prologue: QKV projection, activation sharding, and
    RoPE with the layer-kind theta selection.  Both decode paths (per-layer
    and burst-scheduled) must stay bit-identical, so this lives in one
    place.  Returns ``(q, k, v, window)``."""
    b, s, _ = x.shape
    hd = cfg.resolved_head_dim
    h, hkv = cfg.n_heads, cfg.n_kv_heads
    theta = cfg.rope_theta
    if layer_kind == "A" and cfg.rope_theta_global:
        theta = cfg.rope_theta_global
    window = cfg.sliding_window if layer_kind == "L" else 0
    q = (x @ p["wq"]).reshape(b, s, h, hd)
    k = (x @ p["wk"]).reshape(b, s, hkv, hd)
    v = (x @ p["wv"]).reshape(b, s, hkv, hd)
    q = shard(q, "batch", "seq", "heads", "head_dim")
    k = shard(k, "batch", "seq", "kv_heads", "head_dim")
    v = shard(v, "batch", "seq", "kv_heads", "head_dim")
    if apply_rope:
        q = rope(q, positions, theta)
        k = rope(k, positions, theta)
    return q, k, v, window


def _attn_output(p, out):
    """Shared attention epilogue: output projection + sharding."""
    b, s = out.shape[:2]
    out = shard(out, "batch", "seq", "heads", "head_dim")
    y = out.reshape(b, s, -1) @ p["wo"]
    return shard(y, "batch", "seq", "d_model")


def attention_apply(p, x, cfg, *, positions, layer_kind: str,
                    cache: Optional[dict] = None, kv_chunk: int = 0,
                    apply_rope: bool = True, causal: bool = True):
    """Self-attention with optional KV cache.

    Training/prefill: ``cache`` is None → keys from current sequence; returns
    (out, new_kv) where new_kv is the line-major KV for cache installation.
    Decode: ``cache = {"k": [B,T,Hkv,D] line-major, "v": ..., "pos": scalar}``;
    the cache is read through the Medusa KV layout engine (port-major
    head streams) — the paper's read network in production (DESIGN.md §3.1).
    """
    q, k, v, window = _qkv_project(p, x, cfg, positions=positions,
                                   layer_kind=layer_kind,
                                   apply_rope=apply_rope)
    if cache is None:
        out = attention(q, k, v, positions, positions, causal=causal,
                        window=window, kv_chunk=kv_chunk)
        new_kv = {"k": k, "v": v}
    else:
        pos = cache["pos"]            # scalar, or [B] for per-slot serving
        ck = _cache_write(cache["k"], k, pos)
        cv = _cache_write(cache["v"], v, pos)
        t = ck.shape[1]
        kv_pos = jnp.arange(t)
        # single-token decode: q position == pos; [B, T] mask when per-slot
        valid = (kv_pos <= pos if pos.ndim == 0
                 else kv_pos[None, :] <= pos[:, None])
        out = cached_attention(q, ck, cv, pos, kv_pos, valid, window, cfg)
        new_kv = {"k": ck, "v": cv, "pos": pos}
    return _attn_output(p, out), new_kv


def _kv_port_major(c: jax.Array, cfg) -> jax.Array:
    """[B, T, Hkv, D] line-major → [B, Hkv, T, D] port-major via the model's
    fabric (medusa kernel / crossbar / oracle — ``cfg.resolved_fabric``)."""
    return Fabric.for_model(cfg).kv_port_major(c)


# ----------------------------------------------------------------------------
# burst-scheduled KV banking (serving decode)
# ----------------------------------------------------------------------------
#
# The scheduled decode step hoists every full-attention leaf's port-major
# conversion out of the per-layer scan into ONE read-network burst (and the
# conversion back into one write-network burst).  These helpers are the
# relabels between a leaf's natural layout and the network's line/banked
# forms; they assume the port-per-KV-head geometry (leaf Hkv axis == N).

def kv_leaf_to_lines(leaf: jax.Array) -> jax.Array:
    """Line-major KV leaf ``[..., T, Hkv, D]`` → line stream ``[L, N, D]``
    (one timestep = one line across the head ports; leading axes flatten)."""
    return leaf.reshape((-1,) + leaf.shape[-2:])


def banked_to_port_major(banked: jax.Array, lead_shape) -> jax.Array:
    """Read-network output ``[G, N, N, D]`` → port-major ``[..., Hkv, T, D]``
    where ``lead_shape = leaf.shape[:-2]`` (e.g. ``(layers, B, T)``).  A pure
    relabel of the banked buffer: each port reads its own deep-narrow bank."""
    g, n, _, d = banked.shape
    pm = banked.transpose(1, 0, 2, 3).reshape((n,) + tuple(lead_shape) + (d,))
    return jnp.moveaxis(pm, 0, len(lead_shape) - 1)


def port_major_to_banked(pm: jax.Array) -> jax.Array:
    """Port-major ``[..., Hkv, T, D]`` → write-network input ``[G, N, N, D]``
    (inverse of :func:`banked_to_port_major`; the banked layout invariant
    itself lives in :func:`repro.fabric.fabric.pm_to_banked`)."""
    x = jnp.moveaxis(pm, pm.ndim - 3, 0)          # [Hkv, ..., T, D]
    n, d = x.shape[0], x.shape[-1]
    return pm_to_banked(x.reshape(n, -1, d), n)   # [Hkv, L, D] streams


# ----------------------------------------------------------------------------
# shared physical page pool: gather-based decode
# ----------------------------------------------------------------------------
#
# Under ``FabricConfig.paged_pool`` the serving engine backs every
# full-attention leaf with one shared ``[n_pages, page_size, Hkv, D]``
# physical region; a per-slot logical→physical page table indirects each
# slot's time axis into it.  Two decode forms exist:
#
# * **Fused gather** (``FabricConfig.fused_gather``, the default under the
#   pool): the logical→physical indirection is part of the fabric contract.
#   The engine plans the step's live frames host-side
#   (:func:`page_live_plan`) and the scheduler's sparse-extent streams bank
#   ONLY those — the network's traffic scales with live tokens, not pool
#   capacity — with :func:`gather_pool_frames` reduced to the cheap
#   compact→dense relabel on the (live-sized) banked output.
# * **Gather-after-burst** (the fallback): the burst banks the pool's F
#   frames once and the decode step takes the table as an operand,
#   gathering each slot's mapped frames from the network's output in
#   port-major space.
#
# Every valid position gathers exactly the frame the dense layout would
# hold either way, so logits are bit-identical to the dense engine.

# unmapped-frame sentinel: gathers fill zeros, scatters drop (the shared
# sparse-extent value — repro.fabric.scheduler.FRAME_SENTINEL)


def page_gather_indices(page_table: jax.Array, page_size: int,
                        t_depth: int) -> jax.Array:
    """Per-slot page table ``[B, pages_per_slot]`` (``-1`` = unmapped) →
    physical **frame** indices ``[B, t_depth]`` into the pool's flattened
    ``n_pages * page_size`` frame axis.  Unmapped positions get a far
    out-of-range sentinel: gathers fill them with zeros (always behind the
    decode position mask), scatters drop them."""
    t = jnp.arange(t_depth, dtype=jnp.int32)
    pt = page_table[:, t // page_size]                       # [B, T]
    return jnp.where(pt < 0, jnp.int32(_SENTINEL),
                     pt * jnp.int32(page_size) + t % page_size)


def page_live_plan(page_table, page_size: int, t_depth: int, n_ports: int,
                   bucket: int = 0):
    """Host-side plan of a step's live frames for the fused-gather decode.

    ``page_table`` is the host ``int32 [S, pages_per_slot]`` table (``-1``
    unmapped; a slot's mapped logical pages are a prefix — the pool
    allocates them in order).  Returns three ``int32`` numpy arrays:

    * ``live_idx [L_pad]`` — the physical frame index of every live frame,
      slot-major in logical order, sentinel-padded to a multiple of
      ``n_ports`` (then of ``bucket``, to bound retrace churn — padding
      frames gather as zeros and scatter as drops, so they cost only lanes);
    * ``expand [S, t_depth]`` — each dense position's index into the
      compact live list (sentinel where unmapped), i.e. the cheap
      compact→dense relabel applied to the network's live-sized output;
    * ``dense_pos [L_pad]`` — each live frame's flattened dense position
      ``s * t_depth + t`` (the inverse of ``expand`` on the live set),
      used to compact the updated dense view before the write scatter.

    A slot's live extent is ``min(mapped_pages * page_size, t_depth)`` —
    the tail of a partially-used last page is live (it backs upcoming
    decode growth), but frames past the dense depth are not addressable
    and never move."""
    table = np.asarray(page_table)
    s_count = table.shape[0]
    mapped = (table >= 0).sum(axis=1)
    # the mapped-prefix invariant underwrites the whole plan (and the
    # sparse-extent index contract: entries are physical frames or the
    # sentinel, never negative) — a hole inside a row would emit -1-derived
    # frame indices, so fail loudly here rather than corrupt a gather
    if not np.array_equal(table >= 0,
                          np.arange(table.shape[1])[None, :] < mapped[:, None]):
        raise ValueError("page table rows must map a logical-page prefix "
                         "(-1 entries only after the mapped pages)")
    live = np.minimum(mapped * page_size, t_depth)
    unit = max(n_ports, 1)
    l_pad = -(-max(int(live.sum()), 1) // unit) * unit
    if bucket:
        l_pad = -(-l_pad // bucket) * bucket
    live_idx = np.full((l_pad,), _SENTINEL, np.int32)
    expand = np.full((s_count, t_depth), _SENTINEL, np.int32)
    dense_pos = np.full((l_pad,), _SENTINEL, np.int32)
    off = 0
    for s in range(s_count):
        m = int(live[s])
        if not m:
            continue
        t = np.arange(m)
        live_idx[off:off + m] = (table[s, t // page_size] * page_size
                                 + t % page_size)
        expand[s, :m] = off + t
        dense_pos[off:off + m] = s * t_depth + t
        off += m
    return live_idx, expand, dense_pos


def pool_rep_indices(idx: jax.Array, reps: int, frames: int) -> jax.Array:
    """Tile per-pool frame indices ``idx [K]`` over a leaf's leading layer
    axis: rep ``r``'s pool occupies lines ``[r*frames, (r+1)*frames)`` of
    the flattened line stream, so valid entries shift by ``r*frames`` and
    sentinels stay sentinels.  Returns ``[reps*K]``."""
    offs = jnp.arange(reps, dtype=jnp.int32)[:, None] * jnp.int32(frames)
    tiled = jnp.broadcast_to(idx[None, :], (reps, idx.shape[0]))
    return jnp.where(tiled < frames, tiled + offs,
                     jnp.int32(_SENTINEL)).reshape(-1)


def gather_pool_frames(pool_flat: jax.Array, phys: jax.Array,
                       axis: int) -> jax.Array:
    """Gather per-slot frames from a flattened frame axis at ``axis``:
    ``phys`` (any shape; sentinel/out-of-range = zeros) replaces that axis
    with its own shape in the result.

    This is the thin consumer-side dispatch over the fused-gather contract:
    under ``FabricConfig.fused_gather`` the pool-sized indirection happens
    inside ``Fabric.read_burst(..., indices=)`` (the network banks only
    live frames) and this helper only relabels the live-sized output
    (``expand`` from :func:`page_live_plan`); on the fallback it is the
    full logical→physical gather over the banked pool
    (:func:`page_gather_indices`)."""
    return jnp.take(pool_flat, phys, axis=axis, mode="fill", fill_value=0)


def scatter_pool_frames(pool_flat: jax.Array, dense: jax.Array,
                        phys: jax.Array, axis: int) -> jax.Array:
    """Inverse of :func:`gather_pool_frames`: write the per-slot dense
    frames (``[B, T]`` at ``axis``) back to their mapped physical frames;
    unmapped positions drop.  Mapped frames are owned by exactly one slot
    (the pool's free list never double-maps), so the scatter is exact.
    Under the fused contract the pool-sized form of this lives in
    ``Fabric.write_burst(..., indices=, into=)`` (the gather-after-burst
    fallback is the only remaining pool-sized caller)."""
    idx = [slice(None)] * pool_flat.ndim
    idx[axis] = phys.reshape(-1)
    upd = dense.reshape(dense.shape[:axis] + (-1,) + dense.shape[axis + 2:])
    return pool_flat.at[tuple(idx)].set(upd, mode="drop")


def _pm_cache_write(cache_pm: jax.Array, new: jax.Array,
                    pos: jax.Array) -> jax.Array:
    """Write the new token's K/V at ``pos`` directly in port-major space
    (``cache_pm [B, Hkv, T, D]``, ``new [B, 1, Hkv, D]``; pos scalar or [B]).

    Banking is a permutation, so updating after banking is bit-identical to
    the unscheduled path's update-then-bank."""
    new_pm = jnp.swapaxes(new, 1, 2)
    if pos.ndim == 0:
        return jax.lax.dynamic_update_slice_in_dim(cache_pm, new_pm, pos,
                                                   axis=2)
    return jax.vmap(lambda c, u, p:
                    jax.lax.dynamic_update_slice_in_dim(c, u, p, axis=1)
                    )(cache_pm, new_pm, pos)


def attention_apply_banked(p, x, cfg, *, positions, layer_kind: str,
                           cache: dict):
    """Decode self-attention against a pre-banked port-major KV cache.

    ``cache = {"k_pm"/"v_pm": [B, Hkv, T, D], "pos": scalar or [B]}`` — the
    read network's output for this layer, hoisted into the step's single
    burst by the scheduler.  The new token's K/V is written at ``pos`` in
    port-major space and attention runs on the updated port-major cache —
    bit-identical to :func:`attention_apply`'s cached branch, which updates
    line-major and re-banks per layer.  Returns ``(out, {"k_pm", "v_pm"})``;
    the step's write burst converts the updated caches back to line-major
    once for every layer."""
    q, k, v, window = _qkv_project(p, x, cfg, positions=positions,
                                   layer_kind=layer_kind)
    pos = cache["pos"]
    ck_p = _pm_cache_write(cache["k_pm"], k, pos)
    cv_p = _pm_cache_write(cache["v_pm"], v, pos)
    ck_p = shard(ck_p, "batch", "kv_heads", "kv_seq", "head_dim")
    cv_p = shard(cv_p, "batch", "kv_heads", "kv_seq", "head_dim")
    t = ck_p.shape[2]
    kv_pos = jnp.arange(t)
    valid = (kv_pos <= pos if pos.ndim == 0
             else kv_pos[None, :] <= pos[:, None])
    out = _decode_attention(q, ck_p, cv_p, pos, kv_pos, valid, window)
    return _attn_output(p, out), {"k_pm": ck_p, "v_pm": cv_p}


def _cache_write(cache: jax.Array, new: jax.Array, pos: jax.Array) -> jax.Array:
    """Write the new token's K/V at ``pos`` (scalar, or per-row [B])."""
    if pos.ndim == 0:
        return jax.lax.dynamic_update_slice_in_dim(cache, new, pos, axis=1)
    return jax.vmap(lambda c, n, p:
                    jax.lax.dynamic_update_slice_in_dim(c, n, p, axis=0)
                    )(cache, new, pos)


def _expand_mask(mask: jax.Array) -> jax.Array:
    """[T] or [B, T] decode mask → broadcastable over [B,hkv,g,1,T]."""
    if mask.ndim == 1:
        return mask[None, None, None, None, :]
    return mask[:, None, None, None, :]


def cached_attention(q, ck, cv, pos, kv_pos, valid, window, cfg):
    """Decode attention over a line-major cache, dispatching on the model's
    fabric (``cfg.resolved_fabric.impl`` — the single switch, whether named
    by ``kv_layout`` or an explicit ``FabricConfig``).

    ``medusa``/``crossbar``/``oracle``: re-bank the cache to port-major head
    streams first (the paper's read network; on TPU the medusa form is the
    Pallas exchange-network kernel).  ``fused``: beyond-paper optimisation —
    contract directly against the line-major cache (no materialised copy; the
    layout conversion happens implicitly in the MXU operand load), halving
    cache HBM traffic per step.  All fabrics are value-identical.
    """
    fabric = Fabric.for_model(cfg)
    if fabric.impl == "fused":
        ck = shard(ck, "batch", "kv_seq", "kv_heads", "head_dim")
        cv = shard(cv, "batch", "kv_seq", "kv_heads", "head_dim")
        return _decode_attention_linemajor(q, ck, cv, pos, kv_pos, valid,
                                           window)
    ck_p, cv_p = fabric.kv_port_major(ck), fabric.kv_port_major(cv)
    ck_p = shard(ck_p, "batch", "kv_heads", "kv_seq", "head_dim")
    cv_p = shard(cv_p, "batch", "kv_heads", "kv_seq", "head_dim")
    return _decode_attention(q, ck_p, cv_p, pos, kv_pos, valid, window)


def _decode_attention_linemajor(q, k, v, pos, kv_pos, valid, window):
    """Fused decode attention: ``q [B,1,H,D]`` x ``k/v [B,T,Hkv,D]``.

    The cache-side dots run in the cache dtype (bf16 x bf16 is MXU-native;
    forcing an f32 ``preferred_element_type`` makes XLA carry an f32 COPY of
    the whole cache through the layer scan).  Only the tiny score tensor is
    upcast for the softmax.
    """
    b, sq, h, d = q.shape
    hkv = k.shape[2]
    g = h // hkv
    qg = q.reshape(b, sq, hkv, g, d) * (d ** -0.5)
    s = jnp.einsum("bqhgd,bthd->bhgqt", qg.astype(k.dtype), k)
    s = s.astype(jnp.float32)
    mask = valid
    if window:
        dist = (pos - kv_pos if pos.ndim == 0
                else pos[:, None] - kv_pos[None, :])
        mask = mask & (dist < window)
    s = jnp.where(_expand_mask(mask), s, jnp.float32(-1e30))
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgqt,bthd->bqhgd", p.astype(v.dtype), v)
    return out.reshape(b, sq, h, d)


def _decode_attention(q, k_pm, v_pm, pos, kv_pos, valid, window):
    """Single-step decode attention over a port-major cache.

    ``q [B,1,H,D]``, ``k_pm/v_pm [B,Hkv,T,D]``.  Cache-side dots in cache
    dtype (see ``_decode_attention_linemajor``)."""
    b, sq, h, d = q.shape
    hkv = k_pm.shape[1]
    g = h // hkv
    qg = q.reshape(b, sq, hkv, g, d) * (d ** -0.5)
    s = jnp.einsum("bqhgd,bhkd->bhgqk", qg.astype(k_pm.dtype), k_pm)
    s = s.astype(jnp.float32)
    mask = valid
    if window:
        dist = (pos - kv_pos if pos.ndim == 0
                else pos[:, None] - kv_pos[None, :])
        mask = mask & (dist < window)
    s = jnp.where(_expand_mask(mask), s, jnp.float32(-1e30))
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgqk,bhkd->bqhgd", p.astype(v_pm.dtype), v_pm)
    return out.reshape(b, sq, h, d)


# ----------------------------------------------------------------------------
# MLP
# ----------------------------------------------------------------------------

def mlp_params(key, d_model, d_ff, kind, dtype) -> dict:
    ks = jax.random.split(key, 3)
    p = {"w_out": dense_init(ks[2], d_ff, d_model, dtype)}
    if kind in ("swiglu", "geglu"):
        p["w_gate"] = dense_init(ks[0], d_model, d_ff, dtype)
        p["w_up"] = dense_init(ks[1], d_model, d_ff, dtype)
    else:
        p["w_up"] = dense_init(ks[1], d_model, d_ff, dtype)
    return p


def mlp_apply(p, x, kind: str) -> jax.Array:
    if kind in ("swiglu", "geglu"):
        act = jax.nn.silu if kind == "swiglu" else (
            lambda u: jax.nn.gelu(u, approximate=True))
        h = act(x @ p["w_gate"]) * (x @ p["w_up"])
    else:
        h = jax.nn.gelu(x @ p["w_up"], approximate=True)
    h = shard(h, "batch", "seq", "d_ff")
    return shard(h @ p["w_out"], "batch", "seq", "d_model")


# ----------------------------------------------------------------------------
# embeddings / head / loss
# ----------------------------------------------------------------------------

def embed_params(key, cfg, dtype) -> dict:
    v = pad_vocab(cfg.vocab_size)
    p = {"table": trunc_normal(key, (v, cfg.d_model), dtype,
                               1.0 / math.sqrt(cfg.d_model))}
    if not cfg.tie_embeddings:
        p["head"] = dense_init(jax.random.fold_in(key, 1), cfg.d_model, v, dtype)
    return p


def embed_apply(p, tokens: jax.Array) -> jax.Array:
    table = shard(p["table"], "vocab", "d_model")
    return shard(jnp.take(table, tokens, axis=0), "batch", "seq", "d_model")


def logits_apply(p, x: jax.Array, cfg) -> jax.Array:
    if cfg.tie_embeddings:
        w = shard(p["table"], "vocab", "d_model")
        logits = jnp.einsum("bsd,vd->bsv", x, w,
                            preferred_element_type=jnp.float32)
    else:
        logits = jnp.einsum("bsd,dv->bsv", x, p["head"],
                            preferred_element_type=jnp.float32)
    return shard(logits, "batch", "seq", "vocab")


def draft_head_params(key, cfg, dtype) -> dict:
    """Medusa-style draft heads: ``cfg.spec_heads`` residual projections
    (``d_model → d_model``, SiLU) off the final-norm hidden state; logits
    come from the shared (tied) unembedding, so a head adds ``d²`` params,
    not ``d·V``."""
    return {"w": jnp.stack([
        dense_init(k, cfg.d_model, cfg.d_model, dtype)
        for k in jax.random.split(key, cfg.spec_heads)])}


def draft_logits(p_draft, x: jax.Array, p_embed, cfg) -> jax.Array:
    """``x [B, S, d]`` (final-norm hidden state) → ``[B, k, V]`` draft-head
    logits off the last position — head i proposes the token i+1 steps
    ahead of the one the real unembedding scores."""
    last = x[:, -1]                                         # [B, d]
    h = last[:, None, :] + jax.nn.silu(
        jnp.einsum("bd,kde->bke", last, p_draft["w"]))      # [B, k, d]
    return logits_apply(p_embed, h, cfg)


def softmax_xent(logits: jax.Array, targets: jax.Array,
                 vocab_size: int) -> jax.Array:
    """Mean cross-entropy; padded vocab entries masked out of the softmax."""
    v = logits.shape[-1]
    if v > vocab_size:
        pad_mask = jnp.arange(v) >= vocab_size
        logits = jnp.where(pad_mask, -1e30, logits)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    return jnp.mean(lse - gold)
