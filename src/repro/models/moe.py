"""Mixture-of-Experts FFN with capacity-based dispatch.

Ports-as-experts is the Medusa mapping (DESIGN.md §3.2): the router evenly
partitions token bandwidth across experts with a *static* capacity (paper
observation 1 — even bandwidth partitioning), and the expert all-to-all can
run either on XLA's native all-to-all (the "crossbar") or on the Medusa ring
schedule (N-1 ``ppermute`` rotations — ``repro/parallel/collectives.py``).

Dispatch is sort-based (no [T, E, C] one-hot): tokens are ranked within their
expert by a stable sort over assignments; tokens past capacity are dropped
(their residual passes through — standard capacity-factor semantics).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.fabric.fabric import Fabric
from repro.models.common import dense_init
from repro.parallel.sharding import shard


def moe_params(key, cfg, dtype) -> dict:
    m = cfg.moe
    ks = jax.random.split(key, 4)
    e, d, f = m.n_experts_padded, cfg.d_model, m.expert_d_ff
    return {
        "router": dense_init(ks[0], d, m.n_experts, jnp.float32),
        "w_gate": jax.vmap(lambda k: dense_init(k, d, f, dtype))(
            jax.random.split(ks[1], e)),
        "w_up": jax.vmap(lambda k: dense_init(k, d, f, dtype))(
            jax.random.split(ks[2], e)),
        "w_out": jax.vmap(lambda k: dense_init(k, f, d, dtype))(
            jax.random.split(ks[3], e)),
    }


def moe_apply(p, x: jax.Array, cfg) -> jax.Array:
    """``x [B, S, d]`` → MoE FFN output, top-k routing with capacity.

    With ``moe.pad_to`` set, the expert dim is padded with dead experts the
    router can never select (logits only cover the real experts); capacity is
    computed over real experts so semantics are unchanged — only the EP
    sharding divisibility improves.
    """
    m = cfg.moe
    fabric = Fabric.for_model(cfg)
    e_pad = m.n_experts_padded
    b, s, d = x.shape
    t = b * s
    xt = x.reshape(t, d)

    logits = (xt.astype(jnp.float32) @ p["router"])               # [T, E_real]
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, m.top_k)                  # [T, k]
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)

    a = top_e.reshape(-1)                                         # [T*k]
    tok = jnp.arange(t * m.top_k) // m.top_k
    # rank within expert via stable sort (even static partition -> capacity)
    order = jnp.argsort(a, stable=True)
    a_sorted = a[order]
    first = jnp.searchsorted(a_sorted, a_sorted, side="left")
    rank_sorted = jnp.arange(t * m.top_k) - first
    rank = jnp.zeros_like(rank_sorted).at[order].set(rank_sorted)
    cap = int(t * m.top_k * m.capacity_factor / m.n_experts) or 1
    keep = rank < cap
    slot = jnp.where(keep, a * cap + rank, e_pad * cap)           # drop→OOB

    # Dispatch moves PAYLOAD with gathers only: the scatter touches 4-byte
    # indices, never the d-wide activations (a payload scatter lowers to
    # full-width routing — the crossbar again; see EXPERIMENTS.md §Perf).
    # The gather itself is the fabric's routing primitive.
    inv = jnp.full((e_pad * cap,), t * m.top_k, jnp.int32)
    inv = inv.at[slot].set(jnp.arange(t * m.top_k, dtype=jnp.int32),
                           mode="drop")                           # [E*C]
    slot_valid = inv < t * m.top_k
    src_tok = jnp.clip(inv // m.top_k, 0, t - 1)
    buf = jnp.where(slot_valid[:, None], fabric.route(xt, src_tok), 0)
    buf = buf.reshape(e_pad, cap, d)
    buf = shard(buf, "experts", "expert_cap", "d_model")

    # expert FFN (swiglu), experts sharded over the model axis (EP)
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, p["w_gate"])) * \
        jnp.einsum("ecd,edf->ecf", buf, p["w_up"])
    h = shard(h, "experts", "expert_cap", None)
    y = jnp.einsum("ecf,efd->ecd", h, p["w_out"])
    y = shard(y, "experts", "expert_cap", "d_model").reshape(e_pad * cap, d)

    # combine: gather per assignment, weight, and reduce over the (static,
    # consecutive) top-k axis by reshape+sum — no scatter-add.
    gathered = jnp.where(keep[:, None],
                         fabric.route(y, jnp.clip(slot, 0, e_pad * cap - 1)),
                         0)
    w = top_p.reshape(-1)[:, None].astype(x.dtype)
    out = (gathered * w).reshape(t, m.top_k, d).sum(axis=1)
    return out.reshape(b, s, d)


def aux_load_balance_loss(p, x: jax.Array, cfg) -> jax.Array:
    """Switch-style load-balance auxiliary loss (fraction x probability)."""
    m = cfg.moe
    t = x.shape[0] * x.shape[1]
    logits = x.reshape(t, -1).astype(jnp.float32) @ p["router"]
    probs = jax.nn.softmax(logits, axis=-1)
    top_e = jnp.argmax(probs, axis=-1)
    frac = jnp.mean(jax.nn.one_hot(top_e, m.n_experts), axis=0)
    imp = jnp.mean(probs, axis=0)
    return m.n_experts * jnp.sum(frac * imp)
