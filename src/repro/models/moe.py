"""Mixture-of-Experts FFN with capacity-based dispatch.

Ports-as-experts is the Medusa mapping (DESIGN.md §3.2): the router evenly
partitions token bandwidth across experts with a *static* capacity (paper
observation 1 — even bandwidth partitioning), and the expert all-to-all can
run either on XLA's native all-to-all (the "crossbar") or on the Medusa ring
schedule (N-1 ``ppermute`` rotations — ``repro/parallel/collectives.py``).

Dispatch is sort-based (no [T, E, C] one-hot): tokens are ranked within their
expert by a stable sort over assignments; tokens past capacity are dropped
(their residual passes through — standard capacity-factor semantics).

Payload movement rides the fabric's burst contract: token→expert is the same
logical→physical indirection as a page table, so dispatch is a
scatter-indexed write into the ``[E, C]`` expert slots (capacity drops become
sentinel rows, exactly like page-scatter sentinels) and combine is a
gather-indexed read per assignment — both :class:`BurstScheduler`
sparse-extent streams sharing the packed/fold/kernel lowering and counted in
:class:`SchedulerStats`.  ``payload="route"`` keeps the bare ``fabric.route``
gathers as the bit-parity reference (``tests/test_moe_fabric.py``).
"""

from __future__ import annotations

import contextlib
from typing import Optional

import jax
import jax.numpy as jnp

from repro.fabric.fabric import Fabric
from repro.fabric.scheduler import (BurstScheduler, FRAME_SENTINEL,
                                    SchedulerStats)
from repro.models.common import dense_init
from repro.parallel.sharding import shard

#: module-level stats sink: the serving engine traces ``moe_apply`` deep
#: inside its jitted step, so it routes accounting here (see
#: :func:`dispatch_stats`) instead of threading a kwarg through every layer.
_DISPATCH_STATS: Optional[SchedulerStats] = None


@contextlib.contextmanager
def dispatch_stats(stats: Optional[SchedulerStats]):
    """Route the traffic accounting of every ``moe_apply`` traced inside the
    block to ``stats``.  Must be active at *trace* time: word counters
    accumulate once per trace (the scheduler convention), while the
    data-dependent ``tokens_dropped`` counter is captured into a debug
    callback that fires per execution."""
    global _DISPATCH_STATS
    prev, _DISPATCH_STATS = _DISPATCH_STATS, stats
    try:
        yield
    finally:
        _DISPATCH_STATS = prev


def moe_params(key, cfg, dtype) -> dict:
    m = cfg.moe
    ks = jax.random.split(key, 4)
    e, d, f = m.n_experts_padded, cfg.d_model, m.expert_d_ff
    return {
        "router": dense_init(ks[0], d, m.n_experts, jnp.float32),
        "w_gate": jax.vmap(lambda k: dense_init(k, d, f, dtype))(
            jax.random.split(ks[1], e)),
        "w_up": jax.vmap(lambda k: dense_init(k, d, f, dtype))(
            jax.random.split(ks[2], e)),
        "w_out": jax.vmap(lambda k: dense_init(k, f, d, dtype))(
            jax.random.split(ks[3], e)),
    }


def _count_dropped(stats: Optional[SchedulerStats], keep: jax.Array) -> None:
    """Accumulate the capacity-drop count into ``stats.tokens_dropped``.
    Concrete (eager) counts add directly; traced counts register a debug
    callback so the counter stays runtime-exact under jit/scan."""
    if stats is None:
        return
    drops = keep.size - jnp.sum(keep, dtype=jnp.int32)
    if isinstance(drops, jax.core.Tracer):
        def _add(d, _s=stats):
            _s.tokens_dropped += int(d)
        jax.debug.callback(_add, drops)
    else:
        stats.tokens_dropped += int(drops)


def _burst_dispatch(fabric: Fabric, xt: jax.Array, tok: jax.Array,
                    keep: jax.Array, slot: jax.Array, ec: int,
                    stats: Optional[SchedulerStats]) -> jax.Array:
    """Dispatch as one sparse-extent write burst: the per-assignment token
    buffer ``xt[tok] [T*k, d]`` is viewed as frames ``[T*k, N, d/N]`` and
    scatter-indexed into the zeroed ``[E*C, d]`` slot pool (dropped
    assignments carry sentinel rows, which the write network drops like any
    page-scatter sentinel; slots no assignment reaches keep their zeros).
    Bit-identical to the masked ``fabric.route`` gather by construction —
    both move copies, never arithmetic."""
    n = fabric.n_ports
    d = xt.shape[1]
    xa = xt[tok]                                            # [T*k, d]
    sidx = jnp.where(keep, slot, FRAME_SENTINEL).astype(jnp.int32)
    pad = -xa.shape[0] % n
    if pad:
        xa = jnp.concatenate([xa, jnp.zeros((pad, d), xa.dtype)])
        sidx = jnp.concatenate(
            [sidx, jnp.full((pad,), FRAME_SENTINEL, jnp.int32)])
    lines = xa.reshape(-1, n, d // n)
    banked = lines.reshape(-1, n, n, d // n).swapaxes(1, 2)
    ec_pad = ec + (-ec % n)
    into = jnp.zeros((ec_pad, n, d // n), xt.dtype)
    sched = BurstScheduler(fabric, stats=stats)
    sched.enqueue_write("moe/dispatch", banked, scatter=sidx, into=into)
    pool = sched.flush()["moe/dispatch"]                    # [EC_pad, N, d/N]
    return pool.reshape(ec_pad, d)[:ec]


def _burst_combine(fabric: Fabric, y: jax.Array, keep: jax.Array,
                   slot: jax.Array,
                   stats: Optional[SchedulerStats]) -> jax.Array:
    """Combine as one sparse-extent read burst: the expert output pool
    ``[E*C, d]`` is the backing line stream and each assignment gathers its
    slot's frame (dropped assignments gather the sentinel → zero frames,
    matching the masked route)."""
    n = fabric.n_ports
    ec, d = y.shape
    k_tot = slot.shape[0]
    src = y
    if ec % n:
        src = jnp.concatenate([src, jnp.zeros((-ec % n, d), y.dtype)])
    lines = src.reshape(-1, n, d // n)
    gidx = jnp.where(keep, slot, FRAME_SENTINEL).astype(jnp.int32)
    pad = -k_tot % n
    if pad:
        gidx = jnp.concatenate(
            [gidx, jnp.full((pad,), FRAME_SENTINEL, jnp.int32)])
    sched = BurstScheduler(fabric, stats=stats)
    sched.enqueue_read("moe/combine", lines, gather=gidx)
    banked = sched.flush()["moe/combine"]                   # [K/N, N, N, d/N]
    return banked.swapaxes(1, 2).reshape(-1, d)[:k_tot]


def moe_apply(p, x: jax.Array, cfg, stats: Optional[SchedulerStats] = None,
              payload: Optional[str] = None) -> jax.Array:
    """``x [B, S, d]`` → MoE FFN output, top-k routing with capacity.

    With ``moe.pad_to`` set, the expert dim is padded with dead experts the
    router can never select (logits only cover the real experts); capacity is
    computed over real experts so semantics are unchanged — only the EP
    sharding divisibility improves.

    ``payload`` selects how dispatch/combine move the activations:
    ``"burst"`` (the default whenever the fabric banks and ``d_model`` splits
    across its ports) lowers both as :class:`BurstScheduler` sparse-extent
    streams; ``"route"`` is the bare ``fabric.route`` gather reference.  The
    two are bit-identical — ``tests/test_moe_fabric.py`` holds the line
    across the pack×fold×kernel matrix.  ``stats`` (or an ambient
    :func:`dispatch_stats` context) receives the burst accounting plus the
    runtime-exact ``tokens_dropped`` counter.
    """
    m = cfg.moe
    fabric = Fabric.for_model(cfg)
    e_pad = m.n_experts_padded
    b, s, d = x.shape
    t = b * s
    xt = x.reshape(t, d)
    if stats is None:
        stats = _DISPATCH_STATS
    if payload is None:
        payload = ("burst" if fabric.banks_kv and d % fabric.n_ports == 0
                   else "route")

    logits = (xt.astype(jnp.float32) @ p["router"])               # [T, E_real]
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, m.top_k)                  # [T, k]
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)

    a = top_e.reshape(-1)                                         # [T*k]
    tok = jnp.arange(t * m.top_k) // m.top_k
    # rank within expert via stable sort (even static partition -> capacity)
    order = jnp.argsort(a, stable=True)
    a_sorted = a[order]
    first = jnp.searchsorted(a_sorted, a_sorted, side="left")
    rank_sorted = jnp.arange(t * m.top_k) - first
    rank = jnp.zeros_like(rank_sorted).at[order].set(rank_sorted)
    cap = int(t * m.top_k * m.capacity_factor / m.n_experts) or 1
    keep = rank < cap
    slot = jnp.where(keep, a * cap + rank, e_pad * cap)           # drop→OOB
    _count_dropped(stats, keep)

    if payload == "burst":
        # dispatch rides the write network: one scatter-indexed sparse
        # burst lands each kept assignment's frame in its expert slot
        # (fused scatter kernel on the medusa fabric, take+network+scatter
        # unrolled elsewhere — the same lowering the page pool uses).
        buf = _burst_dispatch(fabric, xt, tok, keep, slot, e_pad * cap,
                              stats)
    else:
        # route reference: payload moves through gathers only — the
        # scatter touches 4-byte indices, never the d-wide activations.
        inv = jnp.full((e_pad * cap,), t * m.top_k, jnp.int32)
        inv = inv.at[slot].set(jnp.arange(t * m.top_k, dtype=jnp.int32),
                               mode="drop")                       # [E*C]
        slot_valid = inv < t * m.top_k
        src_tok = jnp.clip(inv // m.top_k, 0, t - 1)
        buf = jnp.where(slot_valid[:, None], fabric.route(xt, src_tok), 0)
    buf = buf.reshape(e_pad, cap, d)
    buf = shard(buf, "experts", "expert_cap", "d_model")

    # expert FFN (swiglu), experts sharded over the model axis (EP)
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, p["w_gate"])) * \
        jnp.einsum("ecd,edf->ecf", buf, p["w_up"])
    h = shard(h, "experts", "expert_cap", None)
    y = jnp.einsum("ecf,efd->ecd", h, p["w_out"])
    y = shard(y, "experts", "expert_cap", "d_model").reshape(e_pad * cap, d)

    # combine: gather per assignment, weight, and reduce over the (static,
    # consecutive) top-k axis by reshape+sum — no scatter-add.
    if payload == "burst":
        gathered = _burst_combine(fabric, y, keep, slot, stats)
    else:
        gathered = jnp.where(keep[:, None],
                             fabric.route(y, jnp.clip(slot, 0,
                                                      e_pad * cap - 1)),
                             0)
    w = top_p.reshape(-1)[:, None].astype(x.dtype)
    out = (gathered * w).reshape(t, m.top_k, d).sum(axis=1)
    return out.reshape(b, s, d)


def aux_load_balance_loss(p, x: jax.Array, cfg) -> jax.Array:
    """Switch-style load-balance auxiliary loss (fraction x probability).

    ``frac`` counts **every top-k assignment** — the router actually in use
    dispatches top-k, so the load fraction is the share of all ``T*k``
    assignments each expert receives (it sums to 1, and the loss floors at 1
    under a perfectly balanced router, exactly as in the top-1 Switch form).
    The old ``argmax`` form only counted first choices, so an expert fed
    exclusively by second choices looked idle to the loss while running at
    full capacity.
    """
    m = cfg.moe
    t = x.shape[0] * x.shape[1]
    logits = x.reshape(t, -1).astype(jnp.float32) @ p["router"]
    probs = jax.nn.softmax(logits, axis=-1)
    top_e = jax.lax.top_k(probs, m.top_k)[1]                      # [T, k]
    frac = jnp.mean(jax.nn.one_hot(top_e, m.n_experts), axis=(0, 1))
    imp = jnp.mean(probs, axis=0)
    return m.n_experts * jnp.sum(frac * imp)
