"""RG-LRU recurrent block (RecurrentGemma / Griffin, arXiv:2402.19427).

The recurrence ``h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)`` with
``a_t = exp(-c * softplus(Lambda) * r_t)`` is linear in ``h``, so training
uses ``jax.lax.associative_scan`` over the sequence (log-depth, shardable);
decode is the single-step recurrence on an O(1) state.

Block structure (Griffin recurrent block): input projections to two branches
of width ``lru_width``; branch 1 passes a short causal conv then the RG-LRU;
branch 2 is a GeLU gate; merged output projects back to ``d_model``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import dense_init, trunc_normal
from repro.models.mamba2 import _causal_conv
from repro.parallel.sharding import shard


def rglru_params(key, cfg, dtype) -> dict:
    r = cfg.rglru
    w = r.lru_width or cfg.d_model
    ks = jax.random.split(key, 6)
    return {
        "w_branch": dense_init(ks[0], cfg.d_model, 2 * w, dtype),
        "conv_w": trunc_normal(ks[1], (r.conv_width, w), dtype, 0.1),
        "conv_b": jnp.zeros((w,), dtype),
        "w_a": dense_init(ks[2], w, w, jnp.float32),
        "b_a": jnp.zeros((w,), jnp.float32),
        "w_i": dense_init(ks[3], w, w, jnp.float32),
        "b_i": jnp.zeros((w,), jnp.float32),
        # Lambda init so that a^c = sigmoid(lam)^c spans ~(0.9, 0.999)
        "lam": jnp.linspace(2.0, 6.0, w).astype(jnp.float32),
        "w_out": dense_init(ks[4], w, cfg.d_model, dtype),
    }


def _gates(p, xb, cfg):
    r_gate = jax.nn.sigmoid(xb.astype(jnp.float32) @ p["w_a"] + p["b_a"])
    i_gate = jax.nn.sigmoid(xb.astype(jnp.float32) @ p["w_i"] + p["b_i"])
    log_a = -cfg.rglru.c * jax.nn.softplus(p["lam"]) * r_gate
    a = jnp.exp(log_a)
    gated_x = i_gate * xb.astype(jnp.float32)
    b = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) * gated_x
    return a, b


def rglru_apply(p, xin: jax.Array, cfg, cache=None):
    """One Griffin recurrent block.  Decode: cache = {"conv": [B,K-1,W],
    "h": [B,W]} with S == 1."""
    b, seq, _ = xin.shape
    r = cfg.rglru
    w = r.lru_width or cfg.d_model
    branches = xin @ p["w_branch"]
    xb, gate = jnp.split(branches, [w], axis=-1)
    xb = shard(xb, "batch", "seq", "inner")

    if cache is None:
        xb, _ = _causal_conv(xb, p["conv_w"], p["conv_b"])
        a, bb = _gates(p, xb, cfg)
        # associative scan over the sequence: (a, b) ∘ (a', b') = (aa', a'b + b')
        def combine(lhs, rhs):
            al, bl = lhs
            ar, br = rhs
            return al * ar, ar * bl + br
        _, h = jax.lax.associative_scan(combine, (a, bb), axis=1)
        new_cache = None
    else:
        xb, new_conv = _causal_conv(xb, p["conv_w"], p["conv_b"], cache["conv"])
        a, bb = _gates(p, xb, cfg)
        h = a[:, 0] * cache["h"] + bb[:, 0]
        new_cache = {"conv": new_conv, "h": h}
        h = h[:, None]

    out = (h.astype(xin.dtype) * jax.nn.gelu(gate, approximate=True))
    out = out @ p["w_out"]
    return shard(out, "batch", "seq", "d_model"), new_cache


def rglru_sequential_ref(p, xin, cfg):
    """Step-by-step oracle for the associative-scan path (tests only)."""
    b, seq, _ = xin.shape
    r = cfg.rglru
    w = r.lru_width or cfg.d_model
    branches = xin @ p["w_branch"]
    xb, gate = jnp.split(branches, [w], axis=-1)
    xb, _ = _causal_conv(xb, p["conv_w"], p["conv_b"])
    a, bb = _gates(p, xb, cfg)
    h = jnp.zeros((b, w), jnp.float32)
    hs = []
    for t in range(seq):
        h = a[:, t] * h + bb[:, t]
        hs.append(h)
    h = jnp.stack(hs, axis=1)
    out = h.astype(xin.dtype) * jax.nn.gelu(gate, approximate=True)
    return out @ p["w_out"]
