"""Decoder-only LM assembler: pattern-scanned blocks over every family.

A model is a tiled ``block_pattern`` (e.g. ``"A"`` dense, ``"LLLLLG"``→
``"LLLLLA"`` gemma3, ``"RRA"`` recurrentgemma, ``"M"`` mamba2).  The pattern
unit is scanned ``reps = n_layers // len(pattern)`` times with stacked
parameters (one compiled body regardless of depth — critical for 80 dry-run
compiles on CPU); remainder layers run unrolled ("tail").

Caches are pytrees stacked the same way, so ``serve_step`` scans decode with
the cache as scan xs/ys.  VLM configs prepend stub patch embeddings.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import common as cm
from repro.models.moe import moe_params, moe_apply
from repro.models.mamba2 import mamba_params, mamba_apply
from repro.models.rglru import rglru_params, rglru_apply
from repro.parallel.sharding import shard


def pattern_unit(cfg: ModelConfig):
    pat = cfg.block_pattern
    reps = cfg.n_layers // len(pat)
    tail = pat[: cfg.n_layers - reps * len(pat)]
    return pat, reps, tail


# ----------------------------------------------------------------------------
# parameters
# ----------------------------------------------------------------------------

def _block_params(key, t: str, cfg: ModelConfig, dtype) -> dict:
    ks = jax.random.split(key, 4)
    p = {"norm1": cm.init_norm(ks[0], cfg.d_model, dtype, cfg.norm)}
    if t in ("A", "L"):
        p["attn"] = cm.attention_block_params(ks[1], cfg, dtype)
    elif t == "R":
        p["rec"] = rglru_params(ks[1], cfg, dtype)
    elif t == "M":
        p["mixer"] = mamba_params(ks[1], cfg, dtype)
        return p                      # mamba block has no separate FFN
    else:
        raise ValueError(f"unknown block type {t!r}")
    p["norm2"] = cm.init_norm(ks[2], cfg.d_model, dtype, cfg.norm)
    if cfg.moe is not None:
        p["ffn"] = moe_params(ks[3], cfg, dtype)
    else:
        p["ffn"] = cm.mlp_params(ks[3], cfg.d_model, cfg.d_ff, cfg.mlp, dtype)
    return p


def init_params(cfg: ModelConfig, key) -> dict:
    dtype = cfg.param_dtype
    unit, reps, tail = pattern_unit(cfg)
    k_emb, k_unit, k_tail, k_fin = jax.random.split(key, 4)
    params = {"embed": cm.embed_params(k_emb, cfg, dtype)}
    unit_params = []
    for i, t in enumerate(unit):
        kt = jax.random.fold_in(k_unit, i)
        if reps > 0:
            stacked = jax.vmap(lambda k: _block_params(k, t, cfg, dtype))(
                jax.random.split(kt, reps))
            unit_params.append(stacked)
    params["unit"] = unit_params
    params["tail"] = [_block_params(jax.random.fold_in(k_tail, i), t, cfg, dtype)
                      for i, t in enumerate(tail)]
    params["final_norm"] = cm.init_norm(k_fin, cfg.d_model, dtype, cfg.norm)
    if cfg.spec_heads:
        params["draft"] = cm.draft_head_params(
            jax.random.fold_in(key, 0xD4AF7), cfg, dtype)
    return params


# ----------------------------------------------------------------------------
# caches (decode)
# ----------------------------------------------------------------------------

def _block_cache(t: str, cfg: ModelConfig, batch: int, t_max: int, dtype,
                 pool=None):
    hd = cfg.resolved_head_dim
    if t in ("A", "L"):
        if pool is not None and _full_attn(t, cfg):
            # shared physical page pool: one [n_pages, page_size, Hkv, D]
            # region per full-attention leaf — slots reach their frames
            # through the engine's logical→physical page table, so the
            # leaf has no per-slot batch axis at all
            n_pages, page_size = pool
            return {"k": jnp.zeros((n_pages, page_size, cfg.n_kv_heads, hd),
                                   dtype),
                    "v": jnp.zeros((n_pages, page_size, cfg.n_kv_heads, hd),
                                   dtype)}
        # local layers only ever need the sliding window
        length = min(t_max, cfg.sliding_window) if (
            t == "L" and cfg.sliding_window) else t_max
        return {"k": jnp.zeros((batch, length, cfg.n_kv_heads, hd), dtype),
                "v": jnp.zeros((batch, length, cfg.n_kv_heads, hd), dtype)}
    if t == "R":
        w = (cfg.rglru.lru_width or cfg.d_model)
        return {"conv": jnp.zeros((batch, cfg.rglru.conv_width - 1, w), dtype),
                "h": jnp.zeros((batch, w), jnp.float32)}
    if t == "M":
        s = cfg.ssm
        d_in = s.expand * cfg.d_model
        nh = d_in // s.head_dim
        return {"conv": jnp.zeros((batch, s.conv_width - 1,
                                   d_in + 2 * s.d_state), dtype),
                "state": jnp.zeros((batch, nh, s.head_dim, s.d_state),
                                   jnp.float32)}
    raise ValueError(t)


def init_cache(cfg: ModelConfig, batch: int, t_max: int,
               pool_pages: int = 0, page_size: int = 0) -> dict:
    """The batched decode-cache pytree.  With ``pool_pages > 0`` every
    full-attention leaf is backed by a shared physical page pool
    ``[pool_pages, page_size, Hkv, D]`` instead of a dense per-slot
    ``[batch, t_max]`` reservation (ring/recurrent/SSM leaves keep their
    per-slot layout — they are O(window)/O(1) in time)."""
    dtype = cfg.param_dtype
    pool = (pool_pages, page_size) if pool_pages else None
    unit, reps, tail = pattern_unit(cfg)
    unit_caches = []
    for t in unit:
        if reps > 0:
            c = _block_cache(t, cfg, batch, t_max, dtype, pool=pool)
            unit_caches.append(jax.tree.map(
                lambda x: jnp.broadcast_to(x, (reps,) + x.shape), c))
    return {"unit": unit_caches,
            "tail": [_block_cache(t, cfg, batch, t_max, dtype, pool=pool)
                     for t in tail]}


def paged_entries(cfg: ModelConfig):
    """The ``(kind, index)`` cache entries the paged pool backs: every
    full-attention layer's ``k``/``v`` (the same set the burst plan banks).
    Ring, recurrent and SSM caches stay dense per-slot."""
    unit, reps, tail = pattern_unit(cfg)
    out = []
    for kind, types in (("unit", unit if reps > 0 else ""), ("tail", tail)):
        for i, t in enumerate(types):
            if _full_attn(t, cfg):
                out.append((kind, i))
    return out


# ----------------------------------------------------------------------------
# forward
# ----------------------------------------------------------------------------

def _sliding_cache_update(cache_kv, k_new, pos, window):
    """Ring-buffer write for local-attention caches (bounded memory at 500k);
    ``pos`` may be scalar or per-row [B] (serving)."""
    slot = pos % cache_kv.shape[1]
    if pos.ndim == 0:
        return jax.lax.dynamic_update_slice_in_dim(cache_kv, k_new, slot,
                                                   axis=1)
    return jax.vmap(lambda c, n, p:
                    jax.lax.dynamic_update_slice_in_dim(c, n, p, axis=0)
                    )(cache_kv, k_new, slot)


def _block_apply(t: str, bp: dict, x, cfg: ModelConfig, *, positions,
                 cache=None, pos=None, kv_chunk=0, pm_cache=None):
    h = cm.apply_norm(x, bp["norm1"], cfg.norm)
    new_cache = None
    if t in ("A", "L"):
        if pm_cache is not None:
            # burst-scheduled decode: this layer's cache arrived port-major
            # from the step's shared read burst; attend/update in that form
            # and let the step's write burst restore line-major afterwards.
            qpos = pos[None] if pos.ndim == 0 else pos[:, None]
            h, new_cache = cm.attention_apply_banked(
                bp["attn"], h, cfg, positions=qpos, layer_kind=t,
                cache={"k_pm": pm_cache["k_pm"], "v_pm": pm_cache["v_pm"],
                       "pos": pos})
        elif cache is not None:
            # local layers always use a ring (windowed) cache in decode —
            # bounded memory even at 500k context.
            acache = {"k": cache["k"], "v": cache["v"], "pos": pos,
                      "ring": bool(t == "L" and cfg.sliding_window)}
            h, kv = _attn_cached(bp["attn"], h, cfg, t, acache, kv_chunk)
            new_cache = {"k": kv["k"], "v": kv["v"]}
        else:
            h, _ = cm.attention_apply(bp["attn"], h, cfg, positions=positions,
                                      layer_kind=t, cache=None,
                                      kv_chunk=kv_chunk)
    elif t == "R":
        h, new_cache = rglru_apply(bp["rec"], h, cfg, cache)
    elif t == "M":
        h, new_cache = mamba_apply(bp["mixer"], h, cfg, cache)
        return x + h, new_cache       # mamba block: mixer only
    x = x + h
    h = cm.apply_norm(x, bp["norm2"], cfg.norm)
    if cfg.moe is not None:
        h = moe_apply(bp["ffn"], h, cfg)
    else:
        h = cm.mlp_apply(bp["ffn"], h, cfg.mlp)
    return x + h, new_cache


def _attn_cached(p, x, cfg, layer_kind, cache, kv_chunk):
    """Decode-path attention with either a full or ring (windowed) cache."""
    ring = cache.pop("ring", False)
    cpos = cache["pos"]
    qpos = cpos[None] if cpos.ndim == 0 else cpos[:, None]
    if not ring:
        return cm.attention_apply(p, x, cfg, positions=qpos,
                                  layer_kind=layer_kind, cache=cache,
                                  kv_chunk=kv_chunk)
    # ring cache: positions of slots are pos - window + 1 .. pos (mod window)
    b, s, _ = x.shape
    hd = cfg.resolved_head_dim
    h, hkv = cfg.n_heads, cfg.n_kv_heads
    pos = cache["pos"]
    win = cache["k"].shape[1]
    q = (x @ p["wq"]).reshape(b, s, h, hd)
    k = (x @ p["wk"]).reshape(b, s, hkv, hd)
    v = (x @ p["wv"]).reshape(b, s, hkv, hd)
    q = cm.rope(q, qpos, cfg.rope_theta)
    k = cm.rope(k, qpos, cfg.rope_theta)
    ck = _sliding_cache_update(cache["k"], k, pos, win)
    cv = _sliding_cache_update(cache["v"], v, pos, win)
    slots = jnp.arange(win)
    if pos.ndim == 0:
        slot_pos = pos - ((pos - slots) % win)  # absolute position per slot
        valid = (slot_pos >= 0) & (slot_pos <= pos)
        out = cm.cached_attention(q, ck, cv, pos, slot_pos, valid, 0, cfg)
    else:
        slot_pos = pos[:, None] - ((pos[:, None] - slots[None, :]) % win)
        valid = (slot_pos >= 0) & (slot_pos <= pos[:, None])
        # per-row kv positions: fold into the mask (window already enforced
        # by the ring size); use row-wise attention via the generic mask path
        out = _ring_attention_per_row(q, ck, cv, slot_pos, valid, cfg)
    y = out.reshape(b, s, h * hd) @ p["wo"]
    return y, {"k": ck, "v": cv}


def _ring_attention_per_row(q, ck, cv, slot_pos, valid, cfg):
    """Ring-cache decode attention with per-row slot positions (serving)."""
    b, sq, h, d = q.shape
    hkv = ck.shape[2]
    g = h // hkv
    qg = q.reshape(b, sq, hkv, g, d) * (d ** -0.5)
    s = jnp.einsum("bqhgd,bthd->bhgqt", qg.astype(ck.dtype), ck)
    s = s.astype(jnp.float32)
    s = jnp.where(valid[:, None, None, None, :], s, jnp.float32(-1e30))
    p_attn = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgqt,bthd->bqhgd", p_attn.astype(cv.dtype), cv)
    return out.reshape(b, sq, h, d)


def _scan_blocks(params, x, cfg, *, positions, caches=None, pos=None,
                 kv_chunk=0, remat=True, pm_caches=None):
    unit, reps, tail = pattern_unit(cfg)
    pm_unit = pm_caches["unit"] if pm_caches is not None else [None] * len(unit)
    pm_tail = pm_caches["tail"] if pm_caches is not None else [None] * len(tail)

    if reps > 0:
        def body(carry, xs):
            h = carry
            if caches is None:
                unit_p = xs
                new_cs = None
                for t, bp in zip(unit, unit_p):
                    h, _ = _block_apply(t, bp, h, cfg, positions=positions,
                                        kv_chunk=kv_chunk)
            else:
                unit_p, unit_c, unit_pm = xs
                new_cs = []
                for t, bp, c, pmc in zip(unit, unit_p, unit_c, unit_pm):
                    h, nc = _block_apply(t, bp, h, cfg, positions=positions,
                                         cache=c, pos=pos, kv_chunk=kv_chunk,
                                         pm_cache=pmc)
                    new_cs.append(nc)
            return h, new_cs

        if remat and cfg.remat != "none":
            policy = (jax.checkpoint_policies.dots_with_no_batch_dims_saveable
                      if cfg.remat == "dots" else None)
            body = jax.checkpoint(body, policy=policy)
        xs = (tuple(params["unit"]) if caches is None
              else (tuple(params["unit"]), tuple(caches["unit"]),
                    tuple(pm_unit)))
        x, new_unit_caches = jax.lax.scan(body, x, xs)
    else:
        new_unit_caches = None

    new_tail = []
    for i, t in enumerate(tail):
        c = caches["tail"][i] if caches is not None else None
        x, nc = _block_apply(t, params["tail"][i], x, cfg, positions=positions,
                             cache=c, pos=pos, kv_chunk=kv_chunk,
                             pm_cache=pm_tail[i])
        new_tail.append(nc)
    new_caches = (None if caches is None
                  else {"unit": new_unit_caches, "tail": new_tail})
    return x, new_caches


def forward(params, tokens, cfg: ModelConfig, *, patch_embeds=None,
            kv_chunk: int = 0, remat: bool = True):
    """Training / prefill forward → logits [B, S(+P), V]."""
    x = cm.embed_apply(params["embed"], tokens)
    if cfg.n_patches and patch_embeds is not None:
        x = jnp.concatenate([patch_embeds.astype(x.dtype), x], axis=1)
        x = shard(x, "batch", "seq", "d_model")
    s = x.shape[1]
    positions = jnp.arange(s)
    x, _ = _scan_blocks(params, x, cfg, positions=positions,
                        kv_chunk=kv_chunk, remat=remat)
    x = cm.apply_norm(x, params["final_norm"], cfg.norm)
    return cm.logits_apply(params["embed"], x, cfg)


def _emit_logits(params, x, cfg: ModelConfig, draft: bool) -> jax.Array:
    """Step logits off the final-norm hidden state.  With ``draft`` (and
    draft-head params present) the k Medusa draft heads append their
    proposals along the position axis: ``[B, 1+k, V]`` with row 0 the real
    unembedding — callers that index ``[:, 0]`` (or don't pass ``draft``)
    see exactly the dense logits."""
    logits = cm.logits_apply(params["embed"], x, cfg)
    if draft and "draft" in params:
        logits = jnp.concatenate(
            [logits,
             cm.draft_logits(params["draft"], x, params["embed"], cfg)],
            axis=1)
    return logits


def decode_step(params, token, caches, pos, cfg: ModelConfig, sched=None,
                page_table=None, page_size: int = 0, t_depth: int = 0,
                live_plan=None, shard_plans=None, draft: bool = False):
    """One serving decode step: ``token [B, 1]`` + caches at ``pos`` →
    (logits [B, 1, V], new caches).  KV caches are read through the Medusa
    port-major layout engine (cfg.kv_layout).  With ``draft`` the Medusa
    draft heads ride along: logits become ``[B, 1+k, V]``
    (see :func:`_emit_logits`); cache movement is unchanged.

    With a :class:`repro.fabric.BurstScheduler` (``sched``), every
    full-attention leaf's port-major conversion is hoisted out of the layer
    scan into one shared read burst at the top of the step, attention runs
    (and the new token's K/V is written) in port-major space, and one write
    burst restores line-major caches at the bottom — 1 read + 1 write
    network invocation per dtype per step instead of 2 conversions per
    layer, bit-identical because banking is a permutation that commutes
    with the single-timestep update.  Falls back to the per-layer path when
    the fabric is not on the port-per-KV-head geometry or a leaf's line
    count does not divide N.

    With ``page_table`` (``int32 [B, pages_per_slot]``, ``-1`` = unmapped),
    the full-attention leaves are shared physical page pools
    (:func:`init_cache` with ``pool_pages``): the step gathers each slot's
    mapped frames through the table — after the read burst, in port-major
    space, so the gather composes with the banked layout — attends on the
    gathered dense view, and scatters the updated frames back before the
    write burst.  Bit-identical to the dense layout: every valid position
    gathers exactly the frame the dense cache would hold.  ``page_size``
    and ``t_depth`` (the dense time depth the gather reconstructs) are
    static step parameters.

    With ``live_plan`` (the ``(live_idx, expand, dense_pos)`` operands from
    :func:`repro.models.common.page_live_plan` — ``FabricConfig.
    fused_gather``), the logical→physical gather is fused into the burst
    contract instead: the scheduler's sparse-extent streams bank ONLY the
    live frames the table maps (indices prefetched into the fused burst
    kernel on the kernelized medusa fabric), so the network's traffic
    scales with live tokens rather than pool capacity — bit-identical to
    both the gather-after-burst form and the dense engine.

    With ``shard_plans`` (``{reps: (fetch, place)}`` device operands from
    :func:`repro.fabric.shard_plan`, one per distinct leaf rep count —
    ``FabricConfig.pool_shards > 1``), the fused sparse bursts lower over
    the pool-sharded mesh instead: per-shard fused gathers bridged by one
    collective per stream (:mod:`repro.fabric.sharded`), bit-identical to
    the single-device fused path.  Requires ``live_plan``."""
    pos = jnp.asarray(pos, jnp.int32)
    positions = pos[None] if pos.ndim == 0 else pos[:, None]
    phys = (None if page_table is None
            else cm.page_gather_indices(page_table, page_size, t_depth))
    plan = _burst_plan(cfg, caches) if sched is not None else None
    if plan is not None:
        live = live_plan if phys is not None else None
        return _decode_step_scheduled(params, token, caches, pos, positions,
                                      cfg, sched, plan, phys=phys, live=live,
                                      shard_plans=(shard_plans
                                                   if live is not None
                                                   else None), draft=draft)
    if phys is not None:
        return _decode_step_paged_fallback(params, token, caches, pos,
                                           positions, cfg, phys, draft=draft)
    x = cm.embed_apply(params["embed"], token)
    x, new_caches = _scan_blocks(params, x, cfg, positions=positions,
                                 caches=caches, pos=pos, remat=False)
    x = cm.apply_norm(x, params["final_norm"], cfg.norm)
    return _emit_logits(params, x, cfg, draft), new_caches


def _full_attn(t: str, cfg: ModelConfig) -> bool:
    """Full-depth attention layers (ring/recurrent/SSM caches stay on their
    own decode paths — the fabric's small "control" traffic)."""
    return t in ("A", "L") and not (t == "L" and cfg.sliding_window)


def _burst_plan(cfg: ModelConfig, caches):
    """The cache entries a scheduled decode step routes through the shared
    burst: every full-attention ``k``/``v`` leaf, provided the fabric is on
    the port-per-KV-head geometry (leaf head axis == N) and each leaf's
    flattened line count divides N.  Returns ``[(kind, index), ...]`` or
    None to fall back to the per-layer path.  The ``fused`` fabric never
    banks — its consumers contract against the line-major cache directly,
    so scheduling would materialize exactly the copies it elides."""
    fab = cfg.resolved_fabric
    n = fab.n_ports
    if fab.impl == "fused":
        return None
    if n != cfg.n_kv_heads or fab.lane_width != cfg.resolved_head_dim:
        return None
    unit, reps, tail = pattern_unit(cfg)
    plan = []
    for kind, types in (("unit", unit if reps > 0 else ""), ("tail", tail)):
        for i, t in enumerate(types):
            if not _full_attn(t, cfg):
                continue
            leaf = caches[kind][i]["k"]
            lines = 1
            for s in leaf.shape[:-2]:
                lines *= s
            if leaf.shape[-2] != n or lines % n:
                return None
            plan.append((kind, i))
    return plan or None


def _flat_frames(pool: jax.Array) -> jax.Array:
    """Pool leaf ``[lead..., n_pages, page_size, Hkv, D]`` → flattened frame
    axis ``[lead..., F, Hkv, D]`` (F = n_pages * page_size)."""
    return pool.reshape(pool.shape[:-4] + (-1,) + pool.shape[-2:])


def _decode_step_scheduled(params, token, caches, pos, positions,
                           cfg: ModelConfig, sched, plan, phys=None,
                           live=None, shard_plans=None, draft=False):
    """The burst-scheduled decode step (see :func:`decode_step`).

    Burst 1 (read network): every planned KV leaf — and, under
    ``cfg.serve_fsdp``, every streamable weight leaf (the ZeRO-1 weight
    all-gather traffic) — moves through one read invocation per dtype.
    Burst 2 (write network): the updated port-major caches return to
    line-major.  The issue()/commit() split keeps the transfers overlappable
    with consumer compute under JAX async dispatch / XLA scheduling.

    Under the paged pool (``phys`` — per-slot physical frame indices), the
    bursts carry the pool's F frames instead of the dense [B, t] regions;
    the per-slot gather (and the update's scatter) happens in port-major
    space on the network's output, composing with the banked layout.

    With ``live`` (the fused-gather plan — see :func:`decode_step`), the
    gather moves INTO the bursts: each pool leaf becomes a sparse-extent
    stream banking only its live frames, the dense [B, T] view is a cheap
    relabel of the live-sized output, and the update compacts back through
    the inverse map before the sparse write scatters it into the pool —
    so both networks move ``live`` frames, not ``pool`` frames."""
    fab = cfg.resolved_fabric
    n = fab.n_ports
    if live is not None:
        live_idx, expand, dense_pos = live

    def leaf_reps(leaf):
        """The leaf's leading layer-stack factor (1 for tail leaves)."""
        flat = _flat_frames(leaf)
        reps = 1
        for s in flat.shape[:-3]:
            reps *= s
        return reps

    def leaf_gather_idx(leaf):
        """The leaf's sparse read/scatter indices: the step's live frames,
        tiled over the leaf's leading layer axis (unit leaves stack reps)."""
        flat = _flat_frames(leaf)
        if flat.ndim == 3:                       # tail leaf: [F, N, D]
            return live_idx
        return cm.pool_rep_indices(live_idx, leaf_reps(leaf), flat.shape[-3])

    def leaf_shard(leaf):
        """The leaf's ``shard=`` operand tuple: the step's pre-split
        fetch/place plan for its rep count, plus the static line total."""
        reps = leaf_reps(leaf)
        fetch, place = shard_plans[reps]
        return fetch, place, reps * live_idx.shape[0]

    def leaf_stream(leaf):
        """The leaf's rep-major pool line stream ``[R, F, N, D]`` — the
        explicit rep axis keeps page ownership consistent across reps under
        the pool-sharded ``PartitionSpec``."""
        flat = _flat_frames(leaf)
        if flat.ndim == 3:
            return flat[None]
        return flat.reshape((-1,) + flat.shape[-3:])

    # -- burst 1: weight stream + KV banking --------------------------------
    streamed = None
    if cfg.serve_fsdp:
        streamed = _enqueue_weight_stream(sched, params, n)
    for kind, i in plan:
        for leaf_name in ("k", "v"):
            leaf = caches[kind][i][leaf_name]
            if phys is not None:
                if live is not None and shard_plans is not None:
                    sched.enqueue_read(f"{kind}{i}/{leaf_name}",
                                       leaf_stream(leaf),
                                       shard=leaf_shard(leaf))
                    continue
                flat = _flat_frames(leaf)
                sched.enqueue_read(
                    f"{kind}{i}/{leaf_name}", cm.kv_leaf_to_lines(flat),
                    gather=leaf_gather_idx(leaf) if live is not None
                    else None)
                continue
            sched.enqueue_read(f"{kind}{i}/{leaf_name}",
                               cm.kv_leaf_to_lines(leaf))
    sched.issue()
    moved = sched.commit()
    if streamed is not None:
        params = _rebuild_weight_stream(moved, *streamed)

    pm = {"unit": [None] * len(caches["unit"]),
          "tail": [None] * len(caches["tail"])}
    pm_pools = {}
    for kind, i in plan:
        if phys is None:
            lead = caches[kind][i]["k"].shape[:-2]
            pm[kind][i] = {
                leaf_name + "_pm": cm.banked_to_port_major(
                    moved[f"{kind}{i}/{leaf_name}"], lead)
                for leaf_name in ("k", "v")}
            continue
        flat_shape = _flat_frames(caches[kind][i]["k"]).shape
        if live is not None:
            # the banked output is live-sized: [lead?, Hkv, L_live, D]
            lead = flat_shape[:-3] + (live_idx.shape[0],)
        else:
            lead = flat_shape[:-2]
        entry = {}
        for leaf_name in ("k", "v"):
            # [lead?, Hkv, F|L_live, D]: each port's frame stream
            pool_pm = cm.banked_to_port_major(
                moved[f"{kind}{i}/{leaf_name}"], lead)
            if live is None:
                pm_pools[(kind, i, leaf_name)] = pool_pm
            # fused: expand relabels the compact live frames to the dense
            # [B, T] view; fallback: full logical→physical gather
            dense_pm = cm.gather_pool_frames(
                pool_pm, expand if live is not None else phys,
                pool_pm.ndim - 2)
            # [lead?, Hkv, B, T, D] → [lead?, B, Hkv, T, D]
            entry[leaf_name + "_pm"] = jnp.moveaxis(dense_pm, -3, -4)
        pm[kind][i] = entry

    x = cm.embed_apply(params["embed"], token)
    x, new_caches = _scan_blocks(params, x, cfg, positions=positions,
                                 caches=caches, pos=pos, remat=False,
                                 pm_caches=pm)

    # -- burst 2: updated port-major caches → line-major --------------------
    for kind, i in plan:
        for leaf_name in ("k", "v"):
            new_pm = new_caches[kind][i][leaf_name + "_pm"]
            if phys is not None and live is not None:
                # compact the updated dense view back to live frames and
                # scatter them into the pool through the sparse write burst
                upd = jnp.moveaxis(new_pm, -4, -3)     # [lead?, Hkv, B, T, D]
                flat = upd.reshape(upd.shape[:-3]
                                   + (upd.shape[-3] * upd.shape[-2],)
                                   + upd.shape[-1:])
                compact = cm.gather_pool_frames(flat, dense_pos,
                                                flat.ndim - 2)
                leaf = caches[kind][i][leaf_name]
                if shard_plans is not None:
                    sched.enqueue_write(
                        f"{kind}{i}/{leaf_name}",
                        cm.port_major_to_banked(compact),
                        shard=leaf_shard(leaf), into=leaf_stream(leaf))
                    continue
                sched.enqueue_write(
                    f"{kind}{i}/{leaf_name}",
                    cm.port_major_to_banked(compact),
                    scatter=leaf_gather_idx(leaf),
                    into=cm.kv_leaf_to_lines(_flat_frames(leaf)))
                continue
            if phys is not None:
                # scatter the updated per-slot frames back into the
                # port-major pool before it returns through the write burst
                pool_pm = pm_pools[(kind, i, leaf_name)]
                upd = jnp.moveaxis(new_pm, -4, -3)
                new_pm = cm.scatter_pool_frames(pool_pm, upd, phys,
                                                pool_pm.ndim - 2)
            sched.enqueue_write(f"{kind}{i}/{leaf_name}",
                                cm.port_major_to_banked(new_pm))
    sched.issue()
    lines_back = sched.commit()
    for kind, i in plan:
        shape = caches[kind][i]["k"].shape
        new_caches[kind][i] = {
            leaf_name: lines_back[f"{kind}{i}/{leaf_name}"].reshape(shape)
            for leaf_name in ("k", "v")}

    x = cm.apply_norm(x, params["final_norm"], cfg.norm)
    return _emit_logits(params, x, cfg, draft), new_caches


def _decode_step_paged_fallback(params, token, caches, pos, positions,
                                cfg: ModelConfig, phys, draft=False):
    """Per-layer paged decode (unscheduled, off-geometry, or the ``fused``
    fabric): gather each pool into its dense line-major view, run the
    per-layer path unchanged, scatter the updated frames back.  Bit-parity
    with the dense layout for the same reason as the scheduled form."""
    entries = paged_entries(cfg)
    dense_caches = {"unit": list(caches["unit"]), "tail": list(caches["tail"])}
    for kind, i in entries:
        entry = dict(caches[kind][i])
        for leaf_name in ("k", "v"):
            flat = _flat_frames(entry[leaf_name])
            entry[leaf_name] = cm.gather_pool_frames(flat, phys,
                                                     flat.ndim - 3)
        dense_caches[kind][i] = entry
    x = cm.embed_apply(params["embed"], token)
    x, new_caches = _scan_blocks(params, x, cfg, positions=positions,
                                 caches=dense_caches, pos=pos, remat=False)
    for kind, i in entries:
        entry = dict(new_caches[kind][i])
        for leaf_name in ("k", "v"):
            pool = caches[kind][i][leaf_name]
            flat = cm.scatter_pool_frames(_flat_frames(pool),
                                          entry[leaf_name], phys,
                                          pool.ndim - 4)
            entry[leaf_name] = flat.reshape(pool.shape)
        new_caches[kind][i] = entry
    x = cm.apply_norm(x, params["final_norm"], cfg.norm)
    return _emit_logits(params, x, cfg, draft), new_caches


def _enqueue_weight_stream(sched, params, n: int):
    """ZeRO-1 weight streaming (``serve_fsdp``): queue every weight leaf
    whose size divides N² as a single-group line stream in the step's shared
    read burst — the per-step weight all-gather traffic batches with the KV
    reads through one network invocation per dtype.  Leaves that don't fit
    the line geometry stay resident (control traffic)."""
    leaves, treedef = jax.tree_util.tree_flatten(params)
    streamed = []
    for j, leaf in enumerate(leaves):
        if leaf.size and leaf.size % (n * n) == 0:
            sched.enqueue_read(f"weight_stream/{j}", leaf.reshape(n, n, -1))
            streamed.append(j)
    return leaves, treedef, streamed


def _rebuild_weight_stream(moved, leaves, treedef, streamed):
    """Drain the weight-stream ports: each port reads its own bank back, a
    pure relabel of the banked buffer (the round trip is exact)."""
    leaves = list(leaves)
    for j in streamed:
        banked = moved[f"weight_stream/{j}"]          # [1, N, N, W]
        leaves[j] = jnp.swapaxes(banked[0], 0, 1).reshape(leaves[j].shape)
    return jax.tree_util.tree_unflatten(treedef, leaves)


def prefill(params, tokens, cfg: ModelConfig, t_max: int, *,
            patch_embeds=None, kv_chunk: int = 0):
    """Prefill: forward pass that also installs KV/state caches.

    For the dry-run's ``prefill_32k`` cells we lower this function; caches are
    written line-major (time-contiguous wide lines — the DRAM-friendly layout
    the Medusa read network then re-banks during decode)."""
    b = tokens.shape[0]
    caches = init_cache(cfg, b, t_max)
    x = cm.embed_apply(params["embed"], tokens)
    if cfg.n_patches and patch_embeds is not None:
        x = jnp.concatenate([patch_embeds.astype(x.dtype), x], axis=1)
    s = x.shape[1]
    positions = jnp.arange(s)

    unit, reps, tail = pattern_unit(cfg)

    def fill_block(t, bp, c, h):
        hn = cm.apply_norm(h, bp["norm1"], cfg.norm)
        if t in ("A", "L"):
            out, kv = cm.attention_apply(bp["attn"], hn, cfg,
                                         positions=positions, layer_kind=t,
                                         cache=None, kv_chunk=kv_chunk)
            length = c["k"].shape[1]
            if length >= s:
                ck = jax.lax.dynamic_update_slice_in_dim(c["k"], kv["k"], 0, 1)
                cv = jax.lax.dynamic_update_slice_in_dim(c["v"], kv["v"], 0, 1)
            else:
                # windowed layer: keep last `length` positions, placed at ring
                # slots p % length — a barrel rotation of the window (the
                # paper's rotation unit applied on the time axis).
                ck = jnp.roll(kv["k"][:, s - length:], s % length, axis=1)
                cv = jnp.roll(kv["v"][:, s - length:], s % length, axis=1)
            nc = {"k": ck, "v": cv}
            h = h + out
            hn = cm.apply_norm(h, bp["norm2"], cfg.norm)
            ffn = (moe_apply(bp["ffn"], hn, cfg) if cfg.moe is not None
                   else cm.mlp_apply(bp["ffn"], hn, cfg.mlp))
            return h + ffn, nc
        # recurrent/ssm: run the full-sequence form, then rebuild the final
        # state by a single-step replay of the last token (cheap, exact).
        if t == "R":
            out, _ = rglru_apply(bp["rec"], hn, cfg, None)
            h2 = h + out
            # final state via one cached step over the last position
            nc = _recover_rec_state(bp, hn, cfg, t)
            hn2 = cm.apply_norm(h2, bp["norm2"], cfg.norm)
            ffn = (moe_apply(bp["ffn"], hn2, cfg) if cfg.moe is not None
                   else cm.mlp_apply(bp["ffn"], hn2, cfg.mlp))
            return h2 + ffn, nc
        out, _ = mamba_apply(bp["mixer"], hn, cfg, None)
        nc = _recover_rec_state(bp, hn, cfg, t)
        return h + out, nc

    if reps > 0:
        def body(carry, xs):
            h = carry
            up, uc = xs
            ncs = []
            for t, bp, c in zip(unit, up, uc):
                h, nc = fill_block(t, bp, c, h)
                ncs.append(nc)
            return h, ncs
        body = jax.checkpoint(body) if cfg.remat != "none" else body
        x, new_unit = jax.lax.scan(body, x,
                                   (tuple(params["unit"]), tuple(caches["unit"])))
    else:
        new_unit = None
    new_tail = []
    for i, t in enumerate(tail):
        x, nc = fill_block(t, params["tail"][i], caches["tail"][i], x)
        new_tail.append(nc)
    x = cm.apply_norm(x, params["final_norm"], cfg.norm)
    logits = cm.logits_apply(params["embed"], x[:, -1:], cfg)
    return logits, {"unit": new_unit, "tail": new_tail}


def _recover_rec_state(bp, hn, cfg, t):
    """Recompute the final recurrent state for cache installation by running
    the (associative-scan / chunked) path on the full sequence and taking the
    last step through the cached single-step form."""
    b = hn.shape[0]
    if t == "R":
        seqlen = hn.shape[1]
        # run the associative scan and keep h_T + the conv tail window
        from repro.models.rglru import _gates, _causal_conv  # noqa
        r = cfg.rglru
        w = r.lru_width or cfg.d_model
        branches = hn @ bp["rec"]["w_branch"]
        xb, _ = jnp.split(branches, [w], axis=-1)
        conv_state = jnp.concatenate(
            [jnp.zeros((b, max(r.conv_width - 1 - seqlen, 0), w), hn.dtype),
             xb[:, -min(r.conv_width - 1, seqlen):]], axis=1)
        xbc, _ = _causal_conv(xb, bp["rec"]["conv_w"], bp["rec"]["conv_b"])
        a, bb = _gates(bp["rec"], xbc, cfg)
        def combine(lhs, rhs):
            al, bl = lhs
            ar, br = rhs
            return al * ar, ar * bl + br
        _, hseq = jax.lax.associative_scan(combine, (a, bb), axis=1)
        return {"conv": conv_state, "h": hseq[:, -1]}
    # mamba: recompute chunk states and keep the final one
    from repro.models.mamba2 import _project, _causal_conv as mconv
    s = cfg.ssm
    x, z, bmat, cmat, dt, d_in, nh = _project(bp["mixer"], hn, cfg)
    conv_in = jnp.concatenate([x, bmat, cmat], axis=-1)
    seqlen = hn.shape[1]
    conv_state = jnp.concatenate(
        [jnp.zeros((b, max(s.conv_width - 1 - seqlen, 0), conv_in.shape[-1]),
                   conv_in.dtype),
         conv_in[:, -min(s.conv_width - 1, seqlen):]], axis=1)
    conv_out, _ = mconv(conv_in, bp["mixer"]["conv_w"], bp["mixer"]["conv_b"])
    x, bmat, cmat = jnp.split(conv_out, [d_in, d_in + s.d_state], axis=-1)
    xh = x.reshape(b, seqlen, nh, s.head_dim).astype(jnp.float32)
    dtf = jax.nn.softplus(dt.astype(jnp.float32) + bp["mixer"]["dt_bias"])
    a = -jnp.exp(bp["mixer"]["a_log"])
    da = jnp.exp(dtf * a)
    log_da = jnp.log(jnp.maximum(da, 1e-30))
    cum = jnp.cumsum(log_da, axis=1)
    decay_to_end = jnp.exp(cum[:, -1:][:, 0][:, None] - cum)
    state = jnp.einsum("bjh,bjh,bjn,bjhp->bhpn", decay_to_end, dtf,
                       bmat.astype(jnp.float32), xh)
    return {"conv": conv_state, "state": state}
