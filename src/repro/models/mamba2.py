"""Mamba-2 (SSD — state-space duality) blocks, arXiv:2405.21060.

Training uses the chunked SSD algorithm: the sequence is split into chunks of
``Q``; within a chunk the dual quadratic (attention-like) form computes local
interactions, while a ``lax.scan`` over chunks carries the [H, P, N] state
with per-chunk decay — sub-quadratic overall and scan-friendly for sharding.
Decode is the O(1) recurrence ``h = dA h + dt B x``.

The Medusa mapping: SSD state banks are deep-narrow (per-head [P, N] banks)
fed by wide line-major chunk updates — the interconnect's banked-buffer
pattern (DESIGN.md §6).
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models.common import dense_init, trunc_normal, rms_norm
from repro.parallel.sharding import shard


def mamba_params(key, cfg, dtype) -> dict:
    s = cfg.ssm
    d_in = s.expand * cfg.d_model
    nh = d_in // s.head_dim
    ks = jax.random.split(key, 8)
    return {
        # separate projections so each can carry its own sharding:
        # x/z over the (TP-sharded) inner dim, B/C/dt replicated (small).
        "w_xz": dense_init(ks[0], cfg.d_model, 2 * d_in, dtype),
        "w_bc": dense_init(ks[3], cfg.d_model, 2 * s.d_state, dtype),
        "w_dt": dense_init(ks[4], cfg.d_model, nh, dtype),
        "conv_w": trunc_normal(ks[1], (s.conv_width, d_in + 2 * s.d_state),
                               dtype, 0.1),
        "conv_b": jnp.zeros((d_in + 2 * s.d_state,), dtype),
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, nh)).astype(jnp.float32),
        "dt_bias": jnp.zeros((nh,), jnp.float32),
        "d_skip": jnp.ones((nh,), jnp.float32),
        "gate_norm": jnp.zeros((d_in,), dtype),
        "w_out": dense_init(ks[2], d_in, cfg.d_model, dtype),
    }


def _project(p, xin, cfg):
    s = cfg.ssm
    d_in = s.expand * cfg.d_model
    nh = d_in // s.head_dim
    xz = xin @ p["w_xz"]
    x, z = jnp.split(xz, [d_in], axis=-1)
    bc = xin @ p["w_bc"]
    bmat, cmat = jnp.split(bc, [s.d_state], axis=-1)
    dt = xin @ p["w_dt"]
    return x, z, bmat, cmat, dt, d_in, nh


def _causal_conv(x, w, b, state=None):
    """Depthwise causal conv1d, width K.  ``x [B,S,C]``, ``w [K,C]``.
    With ``state [B,K-1,C]`` performs streaming conv and returns new state."""
    k = w.shape[0]
    if state is None:
        pad = jnp.zeros_like(x[:, : k - 1])
    else:
        pad = state
    xp = jnp.concatenate([pad, x], axis=1)
    out = sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(k)) + b
    new_state = xp[:, -(k - 1):] if k > 1 else None
    return jax.nn.silu(out), new_state


def mamba_apply(p, xin: jax.Array, cfg, cache=None):
    """One Mamba-2 mixer.  Training/prefill: ``cache None``; decode: cache =
    {"conv": [B,K-1,C], "state": [B,H,P,N]} and S must be 1."""
    s = cfg.ssm
    b, seq, _ = xin.shape
    x, z, bmat, cmat, dt, d_in, nh = _project(p, xin, cfg)
    conv_in = jnp.concatenate([x, bmat, cmat], axis=-1)

    if cache is None:
        conv_out, _ = _causal_conv(conv_in, p["conv_w"], p["conv_b"])
    else:
        conv_out, new_conv = _causal_conv(conv_in, p["conv_w"], p["conv_b"],
                                          cache["conv"])
    x, bmat, cmat = jnp.split(conv_out, [d_in, d_in + s.d_state], axis=-1)
    xh = x.reshape(b, seq, nh, s.head_dim)
    xh = shard(xh, "batch", "seq", "inner", None)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])       # [B,S,H]
    a = -jnp.exp(p["a_log"])                                          # [H]
    da = jnp.exp(dt * a)                                              # decay

    if cache is None:
        y = _ssd_chunked(xh, dt, da, bmat, cmat, s.chunk)
        new_cache = None
    else:
        h = cache["state"]                                            # [B,H,P,N]
        xd = xh[:, 0] * dt[:, 0, :, None]                             # [B,H,P]
        hb = jnp.einsum("bhp,bn->bhpn", xd.astype(jnp.float32),
                        bmat[:, 0].astype(jnp.float32))
        h = h * da[:, 0, :, None, None] + hb
        y = jnp.einsum("bhpn,bn->bhp", h, cmat[:, 0].astype(jnp.float32))
        y = y[:, None].astype(xin.dtype)
        new_cache = {"conv": new_conv, "state": h}

    y = y.reshape(b, seq, nh, s.head_dim) + (p["d_skip"][:, None]
                                             * xh.astype(jnp.float32)
                                             ).astype(y.dtype)
    y = y.reshape(b, seq, d_in)
    y = rms_norm(y * jax.nn.silu(z), p["gate_norm"])                  # gated norm
    out = y @ p["w_out"]
    return shard(out, "batch", "seq", "d_model"), new_cache


def _ssd_chunked(xh, dt, da, bmat, cmat, q):
    """Chunked SSD scan.  ``xh [B,S,H,P]``, ``dt/da [B,S,H]``,
    ``bmat/cmat [B,S,N]`` → ``y [B,S,H,P]`` (fp32 inside)."""
    b, seq, h, p_dim = xh.shape
    n = bmat.shape[-1]
    q = min(q, seq)
    orig_seq = seq
    if seq % q:
        # pad to a chunk multiple: dt=0 → padded positions contribute nothing
        # to states; outputs past orig_seq are sliced away.
        pad = q - seq % q
        xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        da = jnp.pad(da, ((0, 0), (0, pad), (0, 0)), constant_values=1.0)
        bmat = jnp.pad(bmat, ((0, 0), (0, pad), (0, 0)))
        cmat = jnp.pad(cmat, ((0, 0), (0, pad), (0, 0)))
        seq = seq + pad
    c = seq // q
    f32 = jnp.float32
    xc = xh.reshape(b, c, q, h, p_dim).astype(f32)
    dtc = dt.reshape(b, c, q, h)
    dac = da.reshape(b, c, q, h)
    bc = bmat.reshape(b, c, q, n).astype(f32)
    cc = cmat.reshape(b, c, q, n).astype(f32)

    log_da = jnp.log(jnp.maximum(dac, 1e-30))
    cum = jnp.cumsum(log_da, axis=2)                                  # [B,C,Q,H]
    total = cum[:, :, -1]                                             # [B,C,H]

    # intra-chunk (dual quadratic form): L[i,j] = exp(cum_i - cum_j) for i>=j.
    # Mask BEFORE exp: the i<j entries have positive exponents whose exp
    # overflows, and where(mask, inf, 0) still propagates NaN in the bwd.
    li = cum[:, :, :, None, :] - cum[:, :, None, :, :]                # [B,C,Q,Q,H]
    mask = jnp.tril(jnp.ones((q, q), bool))
    l_mat = jnp.exp(jnp.where(mask[None, None, :, :, None], li, -1e30))
    scores = jnp.einsum("bcin,bcjn->bcij", cc, bc)                    # [B,C,Q,Q]
    y_intra = jnp.einsum("bcij,bcijh,bcjh,bcjhp->bcihp",
                         scores, l_mat, dtc, xc)

    # chunk states: S_c = sum_j exp(total - cum_j) dt_j B_j ⊗ x_j
    decay_to_end = jnp.exp(total[:, :, None] - cum)                   # [B,C,Q,H]
    states = jnp.einsum("bcjh,bcjh,bcjn,bcjhp->bchnp",
                        decay_to_end, dtc, bc, xc)                    # [B,C,H,N,P]

    # inter-chunk recurrence over chunk axis
    def step(carry, inp):
        s_prev = carry                                                # [B,H,N,P]
        s_new, tot = inp                                              # [B,H,N,P],[B,H]
        s_next = s_prev * jnp.exp(tot)[:, :, None, None] + s_new
        return s_next, s_prev

    init = jnp.zeros((b, h, n, p_dim), f32)
    _, s_prevs = jax.lax.scan(
        step, init, (jnp.moveaxis(states, 1, 0), jnp.moveaxis(total, 1, 0)))
    s_prevs = jnp.moveaxis(s_prevs, 0, 1)                             # [B,C,H,N,P]

    # inter-chunk contribution: decay from chunk start then contract with C
    decay_from_start = jnp.exp(cum)                                   # [B,C,Q,H]
    y_inter = jnp.einsum("bcin,bcih,bchnp->bcihp",
                         cc, decay_from_start, s_prevs)
    y = (y_intra + y_inter).reshape(b, seq, h, p_dim)[:, :orig_seq]
    return y.astype(xh.dtype)


def mamba_sequential_ref(p, xin, cfg):
    """Sequential-recurrence oracle for the chunked SSD path (tests only)."""
    s = cfg.ssm
    b, seq, _ = xin.shape
    x, z, bmat, cmat, dt, d_in, nh = _project(p, xin, cfg)
    conv_out, _ = _causal_conv(jnp.concatenate([x, bmat, cmat], -1),
                               p["conv_w"], p["conv_b"])
    x, bmat, cmat = jnp.split(conv_out, [d_in, d_in + s.d_state], axis=-1)
    xh = x.reshape(b, seq, nh, s.head_dim).astype(jnp.float32)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    a = -jnp.exp(p["a_log"])
    da = jnp.exp(dt * a)
    h = jnp.zeros((b, nh, s.head_dim, s.d_state), jnp.float32)
    ys = []
    for t in range(seq):
        hb = jnp.einsum("bhp,bn->bhpn", xh[:, t] * dt[:, t, :, None],
                        bmat[:, t].astype(jnp.float32))
        h = h * da[:, t, :, None, None] + hb
        ys.append(jnp.einsum("bhpn,bn->bhp", h, cmat[:, t].astype(jnp.float32)))
    y = jnp.stack(ys, axis=1)
    y = y + p["d_skip"][:, None] * xh
    y = y.reshape(b, seq, d_in).astype(xin.dtype)
    y = rms_norm(y * jax.nn.silu(z), p["gate_norm"])
    return y @ p["w_out"]
