"""Whisper-style encoder-decoder (arXiv:2212.04356) on the shared substrate.

Per the assignment, the conv frontend is a STUB: ``input_specs`` supplies
precomputed frame embeddings ``[B, encoder_seq, d_model]`` (the output the
two-conv downsampler would produce).  Positions are sinusoidal, attention is
non-rotary, norms follow ``cfg.norm`` ("ln" for whisper).

Decode uses per-layer self-attention KV caches (line-major, read through the
Medusa layout engine like every other arch) plus precomputed cross-attention
K/V from the encoder output.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import common as cm
from repro.parallel.sharding import shard


def _enc_block_params(key, cfg, dtype):
    ks = jax.random.split(key, 4)
    return {
        "norm1": cm.init_norm(ks[0], cfg.d_model, dtype, cfg.norm),
        "attn": cm.attention_block_params(ks[1], cfg, dtype),
        "norm2": cm.init_norm(ks[2], cfg.d_model, dtype, cfg.norm),
        "ffn": cm.mlp_params(ks[3], cfg.d_model, cfg.d_ff, cfg.mlp, dtype),
    }


def _dec_block_params(key, cfg, dtype):
    ks = jax.random.split(key, 6)
    return {
        "norm1": cm.init_norm(ks[0], cfg.d_model, dtype, cfg.norm),
        "attn": cm.attention_block_params(ks[1], cfg, dtype),
        "norm_x": cm.init_norm(ks[2], cfg.d_model, dtype, cfg.norm),
        "xattn": cm.attention_block_params(ks[3], cfg, dtype),
        "norm2": cm.init_norm(ks[4], cfg.d_model, dtype, cfg.norm),
        "ffn": cm.mlp_params(ks[5], cfg.d_model, cfg.d_ff, cfg.mlp, dtype),
    }


def init_params(cfg: ModelConfig, key) -> dict:
    dtype = cfg.param_dtype
    k_emb, k_enc, k_dec, k_f1, k_f2 = jax.random.split(key, 5)
    return {
        "embed": cm.embed_params(k_emb, cfg, dtype),
        "encoder": jax.vmap(lambda k: _enc_block_params(k, cfg, dtype))(
            jax.random.split(k_enc, cfg.encoder_layers)),
        "decoder": jax.vmap(lambda k: _dec_block_params(k, cfg, dtype))(
            jax.random.split(k_dec, cfg.n_layers)),
        "enc_norm": cm.init_norm(k_f1, cfg.d_model, dtype, cfg.norm),
        "final_norm": cm.init_norm(k_f2, cfg.d_model, dtype, cfg.norm),
    }


def _self_attn(bp, x, cfg, positions, causal, cache=None, kv_chunk=0):
    h = cm.apply_norm(x, bp["norm1"], cfg.norm)
    if cache is None:
        out, kv = cm.attention_apply(bp["attn"], h, cfg, positions=positions,
                                     layer_kind="A", apply_rope=False,
                                     causal=causal, kv_chunk=kv_chunk)
    else:
        out, kv = cm.attention_apply(bp["attn"], h, cfg,
                                     positions=cache["pos"][None],
                                     layer_kind="A", cache=cache,
                                     apply_rope=False)
    return x + out, kv


def _cross_attn(bp, x, cfg, enc_kv):
    """Cross-attention with precomputed encoder K/V (port-major streams)."""
    h = cm.apply_norm(x, bp["norm_x"], cfg.norm)
    b, s, _ = h.shape
    hd = cfg.resolved_head_dim
    q = (h @ bp["xattn"]["wq"]).reshape(b, s, cfg.n_heads, hd)
    k_pm, v_pm = enc_kv                       # [B, Hkv, S_enc, D] port-major
    kv_pos = jnp.arange(k_pm.shape[2])
    valid = jnp.ones_like(kv_pos, dtype=bool)
    out = cm._decode_attention(q, k_pm, v_pm, jnp.int32(0), kv_pos, valid, 0)
    y = out.reshape(b, s, cfg.n_heads * hd) @ bp["xattn"]["wo"]
    return x + y


def _mlp(bp, x, cfg):
    h = cm.apply_norm(x, bp["norm2"], cfg.norm)
    return x + cm.mlp_apply(bp["ffn"], h, cfg.mlp)


def encode(params, frames: jax.Array, cfg: ModelConfig) -> jax.Array:
    """Encoder over stub frame embeddings [B, S_enc, D]."""
    x = frames.astype(cfg.param_dtype)
    x = x + cm.sinusoidal_positions(x.shape[1], cfg.d_model).astype(x.dtype)
    x = shard(x, "batch", "frames", "d_model")
    positions = jnp.arange(x.shape[1])

    def body(h, bp):
        h, _ = _self_attn(bp, h, cfg, positions, causal=False)
        h = _mlp(bp, h, cfg)
        return h, None

    body = jax.checkpoint(body) if cfg.remat != "none" else body
    x, _ = jax.lax.scan(body, x, params["encoder"])
    return cm.apply_norm(x, params["enc_norm"], cfg.norm)


def _enc_cross_kv(params, enc_out, cfg):
    """Precompute per-decoder-layer cross K/V, port-major (medusa layout)."""
    b, s_enc, _ = enc_out.shape
    hd = cfg.resolved_head_dim

    def per_layer(bp):
        k = (enc_out @ bp["xattn"]["wk"]).reshape(b, s_enc, cfg.n_kv_heads, hd)
        v = (enc_out @ bp["xattn"]["wv"]).reshape(b, s_enc, cfg.n_kv_heads, hd)
        return cm._kv_port_major(k, cfg), cm._kv_port_major(v, cfg)

    return jax.vmap(per_layer, in_axes=0)(params["decoder"])


def forward(params, tokens, frames, cfg: ModelConfig,
            kv_chunk: int = 0) -> jax.Array:
    """Training forward: encode frames, decode tokens → logits."""
    enc_out = encode(params, frames, cfg)
    cross_kv = _enc_cross_kv(params, enc_out, cfg)
    x = cm.embed_apply(params["embed"], tokens)
    s = x.shape[1]
    x = x + cm.sinusoidal_positions(s, cfg.d_model).astype(x.dtype)
    positions = jnp.arange(s)

    def body(h, xs):
        bp, ckv = xs
        h, _ = _self_attn(bp, h, cfg, positions, causal=True,
                          kv_chunk=kv_chunk)
        h = _cross_attn(bp, h, cfg, ckv)
        h = _mlp(bp, h, cfg)
        return h, None

    body = jax.checkpoint(body) if cfg.remat != "none" else body
    x, _ = jax.lax.scan(body, x, (params["decoder"], cross_kv))
    x = cm.apply_norm(x, params["final_norm"], cfg.norm)
    return cm.logits_apply(params["embed"], x, cfg)


def init_cache(cfg: ModelConfig, batch: int, t_max: int) -> dict:
    hd = cfg.resolved_head_dim
    dt = cfg.param_dtype
    l = cfg.n_layers
    return {
        "k": jnp.zeros((l, batch, t_max, cfg.n_kv_heads, hd), dt),
        "v": jnp.zeros((l, batch, t_max, cfg.n_kv_heads, hd), dt),
        "cross_k": jnp.zeros((l, batch, cfg.n_kv_heads, cfg.encoder_seq, hd), dt),
        "cross_v": jnp.zeros((l, batch, cfg.n_kv_heads, cfg.encoder_seq, hd), dt),
    }


def prefill(params, tokens, frames, cfg: ModelConfig, t_max: int):
    """Encode + decoder prefill; installs self- and cross-attention caches."""
    enc_out = encode(params, frames, cfg)
    cross_kv = _enc_cross_kv(params, enc_out, cfg)
    b = tokens.shape[0]
    x = cm.embed_apply(params["embed"], tokens)
    s = x.shape[1]
    x = x + cm.sinusoidal_positions(s, cfg.d_model).astype(x.dtype)
    positions = jnp.arange(s)
    hd = cfg.resolved_head_dim

    def body(h, xs):
        bp, ckv = xs
        hn = cm.apply_norm(h, bp["norm1"], cfg.norm)
        out, kv = cm.attention_apply(bp["attn"], hn, cfg, positions=positions,
                                     layer_kind="A", apply_rope=False,
                                     causal=True)
        pad = t_max - s
        ck = jnp.pad(kv["k"], ((0, 0), (0, pad), (0, 0), (0, 0)))
        cv = jnp.pad(kv["v"], ((0, 0), (0, pad), (0, 0), (0, 0)))
        h = h + out
        h = _cross_attn(bp, h, cfg, ckv)
        h = _mlp(bp, h, cfg)
        return h, {"k": ck, "v": cv}

    x, self_kv = jax.lax.scan(body, x, (params["decoder"], cross_kv))
    x = cm.apply_norm(x, params["final_norm"], cfg.norm)
    logits = cm.logits_apply(params["embed"], x[:, -1:], cfg)
    cache = {"k": self_kv["k"], "v": self_kv["v"],
             "cross_k": cross_kv[0], "cross_v": cross_kv[1]}
    return logits, cache


def decode_step(params, token, cache, pos, cfg: ModelConfig):
    """One decoder step with self-cache update + static cross K/V."""
    pos = jnp.asarray(pos, jnp.int32)
    x = cm.embed_apply(params["embed"], token)
    x = x + cm.sinusoidal_at(pos, cfg.d_model).astype(x.dtype)

    def body(h, xs):
        bp, ck, cv, xk, xv = xs
        acache = {"k": ck, "v": cv, "pos": pos}
        h, kv = _self_attn(bp, h, cfg, None, causal=True, cache=acache)
        h = _cross_attn(bp, h, cfg, (xk, xv))
        h = _mlp(bp, h, cfg)
        return h, {"k": kv["k"], "v": kv["v"]}

    x, new_kv = jax.lax.scan(
        body, x, (params["decoder"], cache["k"], cache["v"],
                  cache["cross_k"], cache["cross_v"]))
    x = cm.apply_norm(x, params["final_norm"], cfg.norm)
    logits = cm.logits_apply(params["embed"], x, cfg)
    new_cache = dict(cache, k=new_kv["k"], v=new_kv["v"])
    return logits, new_cache
