"""Medusa-schedule MoE layer: explicit shard_map dispatch.

The pjit/GSPMD MoE (`moe.py`) lets the compiler insert collectives for the
token↔expert redistribution; its cost shows up as all-gathers in §Perf cell
B.  This module is the paper-native alternative: the interconnect's **even
static partition + rotation schedule** made explicit —

1. every rank routes ITS OWN tokens locally (top-k, rank-local capacity —
   paper obs. 1: bandwidth statically, evenly partitioned per port);
2. per-destination fixed-size blocks ``[E_ranks, cap_block, d]`` are
   exchanged with the **ring all-to-all** (N−1 ``ppermute`` rotations — the
   §III-A diagonal schedule on chips, neighbour-aligned and overlappable);
3. each rank runs its local experts over the arrived blocks;
4. results return on the reverse ring and combine locally.

No dynamic cross-shard scatter/gather exists anywhere in the path; every
transfer is a fixed-shape neighbour rotation, exactly the crossbar→rotation
substitution of the paper.  Equivalence with the GSPMD layer (ample
capacity) is asserted in ``tests/test_moe_shardmap.py``.

Usage: experts must divide the mesh axis; each rank owns ``E / n`` experts.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.fabric.fabric import Fabric
from repro.parallel.collectives import axis_size, ring_all_to_all


def moe_apply_shardmap(p, x: jax.Array, cfg, axis_name: str = "model"):
    """Per-rank body (run under shard_map, tokens sharded over axis_name).

    ``x [B_loc, S, d]``; expert weight leaves in ``p`` hold only this rank's
    experts ``[e_loc, ...]``.  Returns ``[B_loc, S, d]``.
    """
    m = cfg.moe
    fabric = Fabric.for_model(cfg)
    n = axis_size(axis_name)
    e_total = m.n_experts_padded
    e_loc = e_total // n
    b, s, d = x.shape
    t = b * s
    xt = x.reshape(t, d)

    # 1. local routing (router weights are replicated)
    logits = xt.astype(jnp.float32) @ p["router"]               # [t, E_real]
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, m.top_k)
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)

    a = top_e.reshape(-1)                                       # [t*k]
    order = jnp.argsort(a, stable=True)
    a_sorted = a[order]
    first = jnp.searchsorted(a_sorted, a_sorted, side="left")
    rank_in_e = jnp.zeros_like(a).at[order].set(
        jnp.arange(t * m.top_k) - first)
    # rank-local capacity per expert: even static partition of the rank's
    # token bandwidth across experts (paper obs. 1)
    cap = max(int(t * m.top_k * m.capacity_factor / m.n_experts), 1)
    keep = rank_in_e < cap
    slot = jnp.where(keep, a * cap + rank_in_e, e_total * cap)

    # gather-only payload staging into [E_total * cap, d] send blocks; the
    # payload moves through the fabric's routing primitive (data-dependent
    # destinations — the one consumer that genuinely needs a crossbar hop)
    inv = jnp.full((e_total * cap,), t * m.top_k, jnp.int32)
    inv = inv.at[slot].set(jnp.arange(t * m.top_k, dtype=jnp.int32),
                           mode="drop")
    valid_slot = inv < t * m.top_k
    src_tok = jnp.clip(inv // m.top_k, 0, t - 1)
    send = jnp.where(valid_slot[:, None], fabric.route(xt, src_tok), 0)

    # 2. ring exchange: block r = the cap*e_loc slots destined to rank r
    send_blocks = send.reshape(n, e_loc * cap, d)
    recv = ring_all_to_all(send_blocks, axis_name)              # [n, e_loc*cap, d]

    # 3. local expert FFN over arrived tokens: [e_loc, n*cap, d]
    buf = recv.reshape(n, e_loc, cap, d).transpose(1, 0, 2, 3) \
              .reshape(e_loc, n * cap, d)
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, p["w_gate"])) * \
        jnp.einsum("ecd,edf->ecf", buf, p["w_up"])
    y = jnp.einsum("ecf,efd->ecd", h, p["w_out"])               # [e_loc, n*cap, d]

    # 4. reverse ring: block r returns to its source rank
    back = y.reshape(e_loc, n, cap, d).transpose(1, 0, 2, 3) \
            .reshape(n, e_loc * cap, d)
    returned = ring_all_to_all(back, axis_name)                 # [n, e_loc*cap, d]
    y_full = returned.reshape(e_total * cap, d)

    # local combine (gather + static top-k reduce)
    gathered = jnp.where(keep[:, None],
                         fabric.route(y_full,
                                      jnp.clip(slot, 0, e_total * cap - 1)), 0)
    w = top_p.reshape(-1)[:, None].astype(x.dtype)
    out = (gathered * w).reshape(t, m.top_k, d).sum(axis=1)
    return out.reshape(b, s, d).astype(x.dtype)


def shard_expert_params(p, rank: jax.Array, n: int, cfg):
    """Slice this rank's experts from full stacked weights (test helper;
    production passes pre-sharded leaves via shard_map in_specs)."""
    m = cfg.moe
    e_loc = m.n_experts_padded // n
    sl = lambda w: lax.dynamic_slice_in_dim(w, rank * e_loc, e_loc, axis=0)
    return {"router": p["router"], "w_gate": sl(p["w_gate"]),
            "w_up": sl(p["w_up"]), "w_out": sl(p["w_out"])}
