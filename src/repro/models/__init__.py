"""Model zoo: dense GQA transformers, gemma3 local:global, Mamba-2 SSD,
RG-LRU hybrids, MoE (EP), whisper enc-dec, VLM backbones."""

from repro.models.api import (init_params, loss_fn, prefill_fn, decode_fn,
                              init_cache, greedy_generate)

__all__ = ["init_params", "loss_fn", "prefill_fn", "decode_fn", "init_cache",
           "greedy_generate"]
