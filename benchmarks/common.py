"""Shared benchmark helpers: timing, HLO op census, CSV emission."""

from __future__ import annotations

import time
from collections import Counter

import jax
import numpy as np


def time_us(fn, *args, iters: int = 20, warmup: int = 3) -> float:
    """Median wall time per call in microseconds (CPU, jitted fn)."""
    for _ in range(warmup):
        out = fn(*args)
    jax.block_until_ready(out)
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        times.append((time.perf_counter() - t0) * 1e6)
    return float(np.median(times))


def hlo_op_census(fn, *args) -> Counter:
    """Counter of HLO opcodes in the optimized module for fn(*args)."""
    txt = jax.jit(fn).lower(*args).compile().as_text()
    census: Counter = Counter()
    for line in txt.splitlines():
        line = line.strip()
        if not line.startswith(("%", "ROOT")) or "=" not in line:
            continue
        rhs = line.split("=", 1)[1]
        import re
        m = re.search(r"\b([a-z][\w\-]*)\(", rhs)
        if m:
            census[m.group(1)] += 1
    return census


def _cost_dict(compiled) -> dict:
    cost = compiled.cost_analysis() or {}
    if isinstance(cost, list):      # older jax: one dict per device
        cost = cost[0] if cost else {}
    return cost


def bytes_accessed(fn, *args) -> float:
    cost = _cost_dict(jax.jit(fn).lower(*args).compile())
    return float(cost.get("bytes accessed", 0.0))


def flops_of(fn, *args) -> float:
    cost = _cost_dict(jax.jit(fn).lower(*args).compile())
    return float(cost.get("flops", 0.0))


def emit(rows: list) -> None:
    """Print ``name,us_per_call,derived`` CSV rows."""
    for name, us, derived in rows:
        print(f"{name},{us if us is not None else ''},{derived}")
