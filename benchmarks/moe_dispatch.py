"""MoE dispatch collective schedule: medusa ring vs XLA all-to-all.

Ports-as-experts (DESIGN.md §3.2): with even static capacity the expert
all-to-all can run as N-1 ppermute rotations.  On 8 host devices we verify
equivalence and compare lowered collective ops + wall time; on real ICI the
rotations are neighbour-aligned and overlap with expert compute.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.launch.mesh import compat_shard_map, make_mesh
from repro.parallel.collectives import ring_all_to_all, xla_all_to_all
from benchmarks.common import emit, time_us, hlo_op_census


def run() -> list:
    n = min(8, jax.device_count())
    if n < 2:
        # re-exec ourselves with 8 host devices and relay the CSV rows
        import os
        import subprocess
        import sys
        env = dict(os.environ,
                   XLA_FLAGS="--xla_force_host_platform_device_count=8")
        r = subprocess.run([sys.executable, "-m", "benchmarks.moe_dispatch"],
                           env=env, capture_output=True, text=True,
                           timeout=420)
        rows = []
        for line in r.stdout.splitlines():
            parts = line.split(",")
            if len(parts) == 3 and parts[0].startswith("moe_dispatch/"):
                rows.append((parts[0],
                             float(parts[1]) if parts[1] else None, parts[2]))
        return rows or [("moe_dispatch/subprocess_failed", None,
                         r.stderr[-120:].replace(",", ";"))]
    mesh = make_mesh((n,), ("x",))
    cap, d = 64, 256
    # each rank holds one [cap, d] block per destination expert
    x = jax.random.normal(jax.random.PRNGKey(0), (n * n, cap, d),
                          dtype=jnp.bfloat16)

    ring = jax.jit(compat_shard_map(lambda a: ring_all_to_all(a, "x"),
                                 mesh=mesh, in_specs=P("x"), out_specs=P("x")))
    xla = jax.jit(compat_shard_map(lambda a: xla_all_to_all(a, "x"),
                                mesh=mesh, in_specs=P("x"), out_specs=P("x")))
    r1, r2 = np.asarray(ring(x), np.float32), np.asarray(xla(x), np.float32)
    assert np.allclose(r1, r2)

    rows = []
    for name, fn in (("ring", ring), ("xla_a2a", xla)):
        census = hlo_op_census(fn, x)
        rows.append((f"moe_dispatch/{name}/us", time_us(fn, x), ""))
        rows.append((f"moe_dispatch/{name}/permutes", None,
                     census.get("collective-permute", 0)))
        rows.append((f"moe_dispatch/{name}/all_to_alls", None,
                     census.get("all-to-all", 0)))
    return rows


if __name__ == "__main__":
    emit(run())
