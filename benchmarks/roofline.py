"""Roofline table from the dry-run results (§Roofline deliverable).

Reads ``results/dryrun/*.json`` and prints, per (arch x shape x mesh): the
three roofline terms, dominant bottleneck, MODEL_FLOPS ratio, and per-device
memory.  ``--markdown`` emits the EXPERIMENTS.md table.
"""

from __future__ import annotations

import glob
import json
import os
import sys

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "results",
                           "dryrun")


def load_results() -> list:
    out = []
    for path in sorted(glob.glob(os.path.join(RESULTS_DIR, "*.json"))):
        with open(path) as f:
            out.append(json.load(f))
    return out


def run() -> list:
    rows = []
    for r in load_results():
        tag = f"{r['arch']}/{r['shape']}/{r['mesh']}"
        if r.get("status") != "ok":
            rows.append((f"roofline/{tag}/status", None, "ERROR"))
            continue
        roof = r["roofline"]
        rows.append((f"roofline/{tag}/bound_s", None,
                     f"{roof['bound_s']:.3e}"))
        rows.append((f"roofline/{tag}/dominant", None, roof["dominant"]))
        ratio = r.get("useful_compute_ratio")
        rows.append((f"roofline/{tag}/useful_ratio", None,
                     f"{ratio:.3f}" if ratio else "n/a"))
    return rows


def markdown() -> str:
    lines = ["| arch | shape | mesh | compute s | memory s | collective s | "
             "dominant | useful ratio | temp GB/dev |",
             "|---|---|---|---|---|---|---|---|---|"]
    for r in load_results():
        if r.get("status") != "ok":
            lines.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                         f"ERROR | | | | | |")
            continue
        roof = r["roofline"]
        mem = (r["memory"].get("temp_bytes") or 0) / 1e9
        ratio = r.get("useful_compute_ratio")
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {roof['compute_s']:.2e} | {roof['memory_s']:.2e} "
            f"| {roof['collective_s']:.2e} | **{roof['dominant']}** "
            f"| {ratio:.3f} | {mem:.2f} |" if ratio is not None else
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {roof['compute_s']:.2e} | {roof['memory_s']:.2e} "
            f"| {roof['collective_s']:.2e} | **{roof['dominant']}** "
            f"| n/a | {mem:.2f} |")
    return "\n".join(lines)


if __name__ == "__main__":
    if "--markdown" in sys.argv:
        print(markdown())
    else:
        from benchmarks.common import emit
        emit(run())
