"""Paper §II-B / §III-D: mux-count complexity model, baseline vs Medusa.

Validates our analytic reproduction against the paper's own claims:
baseline ``W_line x (N-1)`` vs Medusa ``W_line x log2(N)`` one-bit 2-to-1
muxes per direction; BRAM accounting (960 vs 64 at the §IV-C design point);
constant N-cycle latency.  Emits one row per design point.
"""

from __future__ import annotations

from repro.core import (InterconnectConfig, complexity_summary,
                        paper_reported_reductions, PAPER_TABLE2)
from benchmarks.common import emit


def run() -> list:
    rows = []
    for w_line in (128, 256, 512, 1024):
        n = w_line // 16
        cfg = InterconnectConfig(w_line=w_line, w_acc=16,
                                 n_read_ports=n, n_write_ports=n)
        s = complexity_summary(cfg)
        rows.append((f"complexity/mux_reduction/W{w_line}_N{n}", None,
                     f"{s['mux_reduction']:.2f}x"))
        rows.append((f"complexity/medusa_mux_bits/W{w_line}_N{n}", None,
                     s["medusa_mux_bits"]))
        rows.append((f"complexity/baseline_mux_bits/W{w_line}_N{n}", None,
                     s["baseline_mux_bits"]))
    # paper design point checks (Table II + §IV-C)
    cfg = InterconnectConfig()
    s = complexity_summary(cfg)
    lut, ff = paper_reported_reductions()
    rows += [
        ("paper/claimed_lut_reduction", None, f"{lut:.2f}x"),
        ("paper/claimed_ff_reduction", None, f"{ff:.2f}x"),
        ("paper/our_mux_reduction_at_512_32", None,
         f"{s['mux_reduction']:.2f}x"),
        ("paper/brackets_claims", None,
         str(lut <= s["mux_reduction"] + 1.5 and ff <= s["mux_reduction"] + 1.5)),
        ("paper/bram_baseline_if_mapped", None, s["baseline_bram_if_mapped"]),
        ("paper/bram_medusa", None, s["medusa_bram"]),
        ("paper/latency_overhead_cycles", None, s["latency_overhead_cycles"]),
        ("paper/claimed_freq_gain", None,
         f"{PAPER_TABLE2['claimed_freq_gain']}x"),
    ]
    return rows


if __name__ == "__main__":
    emit(run())
