"""Unified burst-scheduled fabric vs per-consumer interconnect calls.

The perf claims measured, on the same 4-stream mixed-width traffic:

* ``per_consumer`` — seed style, one read-network lowering per consumer;
* ``unified_pad`` — PR 1's burst layout (pad-to-widest line-axis concat; the
  network moves the padding);
* ``unified_pad_fold2`` / ``_fold4`` — the pad layout riding the same
  u32/u64 machine-word lanes as the packed cells (the fold divides the
  padded width), so pad-vs-packed at equal fold isolates the packing
  effect from the lane width;
* ``unified_packed`` — word-axis packing at the default fold
  (``word_fold="auto"``: on this all-bf16 traffic the burst folds into u32
  machine-word lanes), measured on the UNROLLED network so the
  medusa-vs-crossbar headline compares network against network — the
  ``..._kernel`` cells A/B the fused lowering on top (serving decode with
  kernels enabled, the production default, takes that path);
* ``unified_packed_fold1`` / ``_fold2`` / ``_fold4`` — the explicit
  machine-word lane folding axis (PR 3): adjacent narrow words fold into
  u32/u64 machine words behind the packing bitcast, halving/quartering the
  lane count every exchange-stage select touches (``_fold4`` needs x64 for
  the u64 lane and only appears then);
* ``unified_packed_kernel`` (medusa only) — the packed burst lowers through
  ONE fused ``pallas_call`` per direction (``Fabric.read_burst`` /
  ``write_burst`` with kernels enabled) instead of the unrolled per-stage
  HLO chain; measured at fold=1 (the PR 2 configuration, so the cell
  isolates the kernel effect on the op census) plus a ``_fold2_kernel``
  combination cell.

Paged-decode cell family (``decode_*``): one KV pool leaf's end-to-end
decode-step movement — read the pool through the network, reconstruct the
dense per-slot view through the page table, scatter the (round-tripped)
update back, write network home — at low (25%) and high (75%) pool
occupancy:

* ``decode_gather_after_occ{25,75}`` — the fallback contract: the burst
  banks EVERY pool frame, the gather is a consumer-side postprocess on the
  network's output (``words_moved`` = pool frames, occupancy-independent);
* ``decode_fused_occ{25,75}`` — the fused contract
  (``FabricConfig.fused_gather``): sparse-extent streams bank only the
  frames the table maps (``words_moved`` = ``words_live`` ∝ occupancy);
  the medusa ``_kernel`` variants lower the indirection + exchange as one
  Pallas launch with the indices prefetched (vLLM paged-attention style).

Both forms are asserted bit-identical — same dense view, same updated pool
— before timing, which is the acceptance bar for the fused-gather contract.

Pool-sharded cell family (``decode_sharded_{1,2,4,8}dev``): the same
read-burst → write-burst decode round trip with the pool's frame axis
sharded over a ``pool`` device mesh axis (``FabricConfig.pool_shards``) —
each shard fuse-gathers the frames it owns, one collective exchange hop
delivers them to the requesting shard.  Cells record wall-clock plus the
split of ``words_moved`` into ``words_cross_shard`` (off-diagonal exchange
blocks that physically leave their owner, bucket padding included) vs
``words_local`` (the diagonal): with round-robin page striping roughly
``(S-1)/S`` of the live traffic crosses, never all of it, and every shard
count is asserted bit-identical to the 1-device fused gather before timing.
Host platforms re-exec these cells in a subprocess under
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (device count is
frozen at first jax import).

MoE dispatch cell family (``moe_dispatch_{route,burst}``): one MoE layer's
token→expert dispatch + combine (``repro.models.moe.moe_apply``) with the
data-dependent movement as bare ``fabric.route`` calls (the crossbar
primitive, invisible to the scheduler census — its word counters read zero
by construction) vs as scatter-/gather-indexed sparse-extent streams on the
burst contract (dispatch scatters token lines into the capacity slots,
sentinel rows absorb the drops; combine gathers each assignment's slot
back), asserted bit-identical before timing; the medusa ``_kernel`` variant
lowers both streams through the fused Pallas bursts.  Cells carry the
dispatch/combine word census plus ``tokens_dropped`` (the capacity drops).

Speculative-decode cell family (``decode_spec_k{2,4}``): one serving decode
step with k Medusa draft heads riding along (``decode_fn(draft=True)``,
step logits ``[B, 1+k, V]``) vs the dense step (``decode_spec_dense``);
row 0 is asserted bit-identical to the dense logits first — the draft rows
are pure bookkeeping input, never the commit path.

We lower every form over the same traffic and compare total HLO ops, gather
census, CPU wall time, and the scheduler word census (moved / padded /
folded / fused-kernel bursts), for the medusa and crossbar fabrics.
Semantics are asserted identical before measuring, and the unified forms run
through the issue()/commit() pipeline.

Results append to ``BENCH_fabric.json`` (dir from ``$BENCH_DIR``, default
cwd) — an append-only perf trajectory: each run adds a record carrying its
git SHA, date and axis settings, and prior records survive, so regressions
across PRs stay visible.  A legacy single-run artifact is migrated into the
first record.

    python -m benchmarks.fabric_unified [--pack {packed,pad,both}]
                                        [--fold {1,2,4} ...]
"""

from __future__ import annotations

import argparse
import dataclasses
import datetime
import json
import os
import socket
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.pipeline import batch_lines
from repro.fabric import BurstScheduler, Fabric, SchedulerStats
from repro.fabric.scheduler import machine_word_dtype
from repro.kernels import ops as kops
from benchmarks.common import emit, time_us, hlo_op_census

N = 8            # ports
D = 64           # KV head_dim (lane width of the kv stream)


def _traffic():
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 3)
    kv = jax.random.normal(ks[0], (16 * N, N, D), jnp.bfloat16)
    wt = jax.random.normal(ks[1], (8 * N, N, 32), jnp.bfloat16)
    moe = jax.random.normal(ks[2], (4 * N, N, 16), jnp.bfloat16)
    toks = np.arange(4 * 128, dtype=np.int32).reshape(4, 128) % 997
    stage = jnp.asarray(batch_lines(toks, N), jnp.bfloat16)
    return kv, wt, moe, stage


def _enqueue_all(sched, kv, wt, moe, stage):
    sched.enqueue_read("kv_read", kv)
    sched.enqueue_read("weight_stream", wt)
    sched.enqueue_read("moe_dispatch", moe)
    sched.enqueue_read("batch_stage", stage)


def _fns(impl: str, pack: str, fold=1):
    fab = Fabric.make(N, impl, pack=pack, word_fold=fold)

    def per_consumer(kv, wt, moe, stage):
        # seed style: one network call per consumer
        return (fab.read(kv), fab.read(wt), fab.read(moe), fab.read(stage))

    def unified(kv, wt, moe, stage):
        sched = BurstScheduler(fab)
        _enqueue_all(sched, kv, wt, moe, stage)
        sched.issue()                      # transfer overlaps consumer compute
        out = sched.commit()
        return (out["kv_read"], out["weight_stream"], out["moe_dispatch"],
                out["batch_stage"])

    return jax.jit(per_consumer), jax.jit(unified)


def _word_census(impl: str, pack: str, fold, args) -> SchedulerStats:
    stats = SchedulerStats()
    sched = BurstScheduler(Fabric.make(N, impl, pack=pack, word_fold=fold),
                           stats=stats)
    _enqueue_all(sched, *args)
    sched.flush()
    return stats


def _paged_workload(occ_pages: int):
    """One pool-backed KV leaf at a controlled occupancy: ``B`` slots each
    holding ``occ_pages`` of their ``pages_per_slot`` logical pages."""
    from repro.models import common as cm

    b, t_depth, ps = 8, 64, 8
    pages_per_slot = t_depth // ps
    pool_pages = b * pages_per_slot
    frames = pool_pages * ps
    pool = jax.random.normal(jax.random.PRNGKey(3), (frames, N, D),
                             jnp.bfloat16)
    table = np.full((b, pages_per_slot), -1, np.int32)
    nxt = 0
    for s in range(b):
        table[s, :occ_pages] = np.arange(nxt, nxt + occ_pages)
        nxt += occ_pages
    live_idx, expand, dense_pos = cm.page_live_plan(table, ps, t_depth, N)
    phys = cm.page_gather_indices(jnp.asarray(table), ps, t_depth)
    return pool, phys, (jnp.asarray(live_idx), jnp.asarray(expand),
                        jnp.asarray(dense_pos))


def _paged_fns(impl: str, fused: bool):
    """The decode step's per-leaf KV movement: read burst → dense per-slot
    view → scatter the update back → write burst.  ``fused`` selects the
    sparse-extent contract (network moves live frames) vs gather-after
    (network moves the pool)."""
    from repro.models import common as cm

    fab = Fabric.make(N, impl)

    def gather_after(pool, phys):
        sched = BurstScheduler(fab)
        sched.enqueue_read("kv", pool)
        banked = sched.flush()["kv"]
        pm = cm.banked_to_port_major(banked, (pool.shape[0],))
        dense = cm.gather_pool_frames(pm, phys, pm.ndim - 2)
        back = cm.scatter_pool_frames(pm, dense, phys, pm.ndim - 2)
        sched = BurstScheduler(fab)
        sched.enqueue_write("kv_w", cm.port_major_to_banked(back))
        return dense, sched.flush()["kv_w"]

    def fused_fn(pool, plan):
        live_idx, expand, dense_pos = plan
        sched = BurstScheduler(fab)
        sched.enqueue_read("kv", pool, gather=live_idx)
        banked = sched.flush()["kv"]
        pm = cm.banked_to_port_major(banked, (live_idx.shape[0],))
        dense = cm.gather_pool_frames(pm, expand, pm.ndim - 2)
        flat = dense.reshape(dense.shape[:-3]
                             + (dense.shape[-3] * dense.shape[-2],)
                             + dense.shape[-1:])
        compact = cm.gather_pool_frames(flat, dense_pos, flat.ndim - 2)
        sched = BurstScheduler(fab)
        sched.enqueue_write("kv_w", cm.port_major_to_banked(compact),
                            scatter=live_idx, into=pool)
        return dense, sched.flush()["kv_w"]

    return jax.jit(fused_fn) if fused else jax.jit(gather_after)


def _paged_census(impl: str, fused: bool, pool, phys, plan) -> SchedulerStats:
    """Traffic census matching the timed cell: one read AND one write burst
    (the decode step's two directions), so words_moved is what the timed
    function actually carried."""
    stats = SchedulerStats()
    sched = BurstScheduler(Fabric.make(N, impl), stats=stats)
    if fused:
        k = plan[0].shape[0]
        sched.enqueue_read("kv", pool, gather=plan[0])
        sched.enqueue_write("kv_w", jnp.zeros((k // N, N, N, D), pool.dtype),
                            scatter=plan[0], into=pool)
    else:
        sched.enqueue_read("kv", pool)
        sched.enqueue_write(
            "kv_w", jnp.zeros((pool.shape[0] // N, N, N, D), pool.dtype))
    sched.flush()
    return stats


def paged_decode_cells(cells: dict, rows: list) -> None:
    """The ``decode_fused`` vs ``decode_gather_after`` A/B at low/high pool
    occupancy (see module docstring).  Asserts bit-parity of the dense view
    and the written-back pool before timing."""
    for occ_pages, tag in ((2, "occ25"), (6, "occ75")):
        pool, phys, plan = _paged_workload(occ_pages)
        for impl in ("medusa", "crossbar"):
            kops.use_kernels(False)
            ref_dense, ref_pool = _paged_fns(impl, fused=False)(pool, phys)
            variants = [(f"decode_gather_after_{tag}", False, False),
                        (f"decode_fused_{tag}", True, False)]
            if impl == "medusa":
                variants.append((f"decode_fused_{tag}_kernel", True, True))
            for name, fused, kern in variants:
                kops.use_kernels(kern)
                fn = _paged_fns(impl, fused)
                arg = plan if fused else phys
                dense, pool_back = fn(pool, arg)
                assert np.array_equal(np.asarray(dense, np.float32),
                                      np.asarray(ref_dense, np.float32)), (
                    impl, name)
                assert np.array_equal(np.asarray(pool_back, np.float32),
                                      np.asarray(ref_pool, np.float32)), (
                    impl, name)
                stats = _paged_census(impl, fused, pool, phys, plan)
                cell = {"us": time_us(fn, pool, arg, iters=30),
                        "words_moved": stats.words_moved,
                        "words_live": stats.words_live,
                        "gather_fused_bursts": stats.gather_fused_bursts,
                        "kernel_bursts": stats.kernel_bursts}
                cells[f"{impl}/{name}"] = cell
                for key, val in cell.items():
                    rows.append((f"fabric_unified/{impl}/{name}/{key}",
                                 val if key == "us" else None,
                                 "" if key == "us" else val))
    kops.use_kernels(False)


SHARD_COUNTS = (1, 2, 4, 8)
_SHARDED_MARK = "SHARDED_CELLS_JSON:"


def _sharded_workload():
    """Pool-backed KV leaf sized so the sharded exchange's bucket padding
    vanishes at S=8: 4 slots x 32 live pages x 8 timesteps = 1024 live
    frames, 16 per (owner, requestor) bucket — already a whole number of
    N-groups, so ``cap`` needs no rounding and ``words_cross_shard`` lands
    at exactly ``(S-1)/S`` of the live traffic.  Physical pages stripe
    round-robin over the 8 finest shard blocks (``PagePool``'s allocation
    order), so every power-of-two coarsening of the ownership blocks stays
    balanced.  (Undersized buckets instead PAD the exchange — at tiny live
    counts the ``S(S-1)·cap`` floor can exceed the live traffic itself,
    which is the real locality tax of sharding a near-empty pool.)"""
    from repro.models import common as cm

    b, pages_per_slot, occ_pages, ps = 4, 32, 32, 8
    pool_pages = b * pages_per_slot           # 128 — divisible by every S
    frames = pool_pages * ps
    blk = pool_pages // max(SHARD_COUNTS)
    table = np.full((b, pages_per_slot), -1, np.int32)
    for i in range(b * occ_pages):
        s, j = divmod(i, occ_pages)
        table[s, j] = (i % max(SHARD_COUNTS)) * blk + i // max(SHARD_COUNTS)
    live_idx, _, _ = cm.page_live_plan(table, ps, pages_per_slot * ps, N)
    pool = jax.random.normal(jax.random.PRNGKey(7), (frames, N, D),
                             jnp.bfloat16)
    return pool, jnp.asarray(live_idx), frames, ps


def _sharded_fab(n_shards: int, collective: str = "all_to_all") -> Fabric:
    from repro.fabric import make_pool_mesh

    fab = Fabric.make(N, "medusa", pool_shards=n_shards,
                      collective=collective)
    if n_shards > 1:
        fab = dataclasses.replace(fab, mesh=make_pool_mesh(n_shards))
    return fab


def _sharded_step(fab: Fabric, k_tot: int, stats=None):
    """The decode round trip (sparse read burst → sparse write burst) on the
    pool-sharded lowering — or the single-device fused gather when the
    fabric isn't sharded (the 1dev baseline cell)."""
    sharded = fab.config.pool_shards > 1

    def step(pool, *ops):
        sched = BurstScheduler(fab, stats=stats)
        if sharded:
            fetch, place = ops
            shard = (fetch, place, k_tot)
            sched.enqueue_read("kv", pool[None], shard=shard)
            banked = sched.flush()["kv"]
            sched = BurstScheduler(fab, stats=stats)
            sched.enqueue_write("kv_w", banked, shard=shard, into=pool[None])
            return banked, sched.flush()["kv_w"][0]
        (live,) = ops
        sched.enqueue_read("kv", pool, gather=live)
        banked = sched.flush()["kv"]
        sched = BurstScheduler(fab, stats=stats)
        sched.enqueue_write("kv_w", banked, scatter=live, into=pool)
        return banked, sched.flush()["kv_w"]

    return step


def _sharded_cells() -> dict:
    """The ``decode_sharded_{S}dev`` cells; needs ``jax.device_count() >=
    max(SHARD_COUNTS)`` (the caller re-execs under forced host devices
    otherwise).  Asserts every shard count bit-identical to the 1-device
    fused gather, and the locality inequality ``words_cross_shard <
    words_moved`` at every S > 1."""
    from repro.fabric import shard_plan

    pool, live_idx, frames, ps = _sharded_workload()
    cells, ref = {}, None
    for s in SHARD_COUNTS:
        if s == 1:
            ops, k_tot = (live_idx,), int(live_idx.shape[0])
        else:
            plan = shard_plan(np.asarray(live_idx), frames, s, N,
                              cap_bucket=ps)
            ops, k_tot = plan.operands(), plan.k_tot
        fab = _sharded_fab(s)
        stats = SchedulerStats()
        fn = jax.jit(_sharded_step(fab, k_tot, stats=stats))
        banked, back = fn(pool, *ops)   # first call traces → census fills
        got = (np.asarray(banked, np.float32), np.asarray(back, np.float32))
        if ref is None:
            ref = got
        else:
            assert np.array_equal(got[0], ref[0]), f"{s}dev banked mismatch"
            assert np.array_equal(got[1], ref[1]), f"{s}dev pool mismatch"
        cell = {"us": time_us(fn, pool, *ops, iters=10),
                "pool_shards": s,
                "words_moved": stats.words_moved,
                "words_cross_shard": stats.words_cross_shard,
                "words_local": stats.words_moved - stats.words_cross_shard,
                "collective_calls": stats.collective_calls}
        if s > 1:
            assert cell["words_cross_shard"] < cell["words_moved"], cell
        cells[f"medusa/decode_sharded_{s}dev"] = cell
    return cells


def sharded_decode_cells(cells: dict, rows: list) -> None:
    """Collect the sharded cells, re-execing this module in a subprocess
    with forced host devices when this process came up with too few (the
    XLA device count is frozen at first jax import, so it cannot be raised
    in-process)."""
    want = max(SHARD_COUNTS)
    if jax.device_count() >= want:
        sub = _sharded_cells()
    else:
        env = dict(os.environ)
        env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "") +
                            f" --xla_force_host_platform_device_count"
                            f"={want}").strip()
        root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
        proc = subprocess.run(
            [sys.executable, "-m", "benchmarks.fabric_unified",
             "--sharded-json"],
            env=env, cwd=root, capture_output=True, text=True)
        marks = [ln for ln in proc.stdout.splitlines()
                 if ln.startswith(_SHARDED_MARK)]
        if proc.returncode or not marks:
            raise RuntimeError(
                "sharded bench subprocess failed:\n"
                + proc.stdout[-1000:] + proc.stderr[-2000:])
        sub = json.loads(marks[-1][len(_SHARDED_MARK):])
    for name, cell in sub.items():
        cells[name] = cell
        for key, val in cell.items():
            rows.append((f"fabric_unified/{name}/{key}",
                         val if key == "us" else None,
                         "" if key == "us" else val))


def _moe_cfg(impl: str):
    from repro.configs.base import FabricConfig, ModelConfig, MoEConfig

    return ModelConfig(
        name=f"bench-moe-{impl}", family="moe", n_layers=1, d_model=D,
        n_heads=4, n_kv_heads=4, d_ff=0, vocab_size=256,
        moe=MoEConfig(n_experts=8, top_k=2, expert_d_ff=128,
                      capacity_factor=1.0),
        fabric=FabricConfig(n_ports=N, lane_width=8, impl=impl))


def moe_dispatch_cells(cells: dict, rows: list) -> None:
    """The ``moe_dispatch_route`` vs ``moe_dispatch_burst`` A/B (see module
    docstring).  Bit-parity of the layer output is asserted before timing;
    the burst census runs eagerly so the word counters and the runtime
    ``tokens_dropped`` land in the cell.  ``capacity_factor=1.0`` on a
    random router makes the capacity genuinely bite."""
    from repro.models import moe as moe_mod

    x = jax.random.normal(jax.random.PRNGKey(5), (8, 64, D), jnp.float32)
    for impl in ("medusa", "crossbar"):
        cfg = _moe_cfg(impl)
        p = moe_mod.moe_params(jax.random.PRNGKey(1), cfg, jnp.float32)
        kops.use_kernels(False)
        ref = np.asarray(moe_mod.moe_apply(p, x, cfg, payload="route"))
        variants = [("moe_dispatch_route", "route", False),
                    ("moe_dispatch_burst", "burst", False)]
        if impl == "medusa":       # crossbar bursts never kernelize
            variants.append(("moe_dispatch_burst_kernel", "burst", True))
        for name, payload, kern in variants:
            kops.use_kernels(kern)
            stats = SchedulerStats()
            got = moe_mod.moe_apply(p, x, cfg, stats=stats, payload=payload)
            assert np.array_equal(np.asarray(got), ref), (impl, name)
            fn = jax.jit(lambda xx, _pl=payload: moe_mod.moe_apply(
                p, xx, cfg, payload=_pl))
            cell = {"us": time_us(fn, x, iters=30),
                    "words_moved": stats.words_moved,
                    "words_live": stats.words_live,
                    "kernel_bursts": stats.kernel_bursts,
                    "tokens_dropped": stats.tokens_dropped}
            cells[f"{impl}/{name}"] = cell
            for key, val in cell.items():
                rows.append((f"fabric_unified/{impl}/{name}/{key}",
                             val if key == "us" else None,
                             "" if key == "us" else val))
    kops.use_kernels(False)


def spec_decode_cells(cells: dict, rows: list) -> None:
    """The ``decode_spec_k{2,4}`` vs ``decode_spec_dense`` A/B: one decode
    step on the starcoder2 smoke config, with and without the Medusa draft
    rows appended.  Row 0 of the spec logits is asserted bit-identical to
    the dense step's before timing (same init key → identical base
    params; the draft heads fold their own key)."""
    from repro.configs import get_smoke
    from repro.models import api as mapi

    base = dataclasses.replace(get_smoke("starcoder2-15b"), dtype="float32")
    caches = mapi.init_cache(base, 4, 32)
    tok = jnp.ones((4, 1), jnp.int32)
    ref = None
    for k in (0, 2, 4):
        cfg = dataclasses.replace(base, spec_heads=k,
                                  name=f"{base.name}-speck{k}")
        params = mapi.init_params(cfg, jax.random.PRNGKey(0))
        fn = jax.jit(lambda p_, t_, c_, _cfg=cfg, _d=k > 0:
                     mapi.decode_fn(p_, t_, c_, 8, _cfg, draft=_d)[0])
        logits = fn(params, tok, caches)
        if k == 0:
            ref = np.asarray(logits)
            name = "decode_spec_dense"
        else:
            assert logits.shape[1] == 1 + k, logits.shape
            assert np.array_equal(np.asarray(logits[:, :1]), ref), k
            name = f"decode_spec_k{k}"
        cell = {"us": time_us(fn, params, tok, caches, iters=30),
                "draft_rows": k}
        cells[f"medusa/{name}"] = cell
        for key, val in cell.items():
            rows.append((f"fabric_unified/medusa/{name}/{key}",
                         val if key == "us" else None,
                         "" if key == "us" else val))


def _git_sha() -> str:
    try:
        return subprocess.check_output(
            ["git", "rev-parse", "--short", "HEAD"],
            cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
            stderr=subprocess.DEVNULL).decode().strip()
    except Exception:
        return "unknown"


def _append_run(path: str, run: dict) -> None:
    """Append-only trajectory: keep every prior run record; migrate a legacy
    single-run (flat dict) artifact into the first record."""
    history = []
    if os.path.exists(path):
        try:
            with open(path) as f:
                old = json.load(f)
        except (OSError, json.JSONDecodeError):
            old = None
        if isinstance(old, dict) and isinstance(old.get("runs"), list):
            history = old["runs"]
        elif isinstance(old, dict):           # legacy flat artifact (PR 2)
            legacy = {"git_sha": "legacy", "date": "unknown",
                      "workload": old.pop("workload", None), "cells": old}
            history = [legacy]
        else:
            # never overwrite a trajectory we can't extend — move the
            # unreadable/unrecognized file aside so the history survives
            aside = path + ".corrupt"
            os.replace(path, aside)
            print(f"# warning: {path} was not a recognized trajectory; "
                  f"moved to {aside}")
    for rec in history:           # backfill pre-metadata records in place
        if rec.get("date") is None:
            rec["date"] = "unknown"
    history.append(run)
    with open(path, "w") as f:
        json.dump({"runs": history}, f, indent=2, sort_keys=True)


def run(packs=("packed", "pad"), folds=(1, 2)) -> list:
    # a fold cell must measure what its name says: drop factors whose
    # machine word doesn't exist for this bf16 traffic (u64 needs x64 —
    # the scheduler would silently degrade the group and mislabel the cell)
    realizable = tuple(f for f in folds
                       if f == 1 or machine_word_dtype(2 * f) is not None)
    for f in folds:
        if f not in realizable:
            print(f"# skipping fold{f} cells: no {2 * f}-byte machine word "
                  f"on this platform (enable x64)")
    folds = realizable
    args = _traffic()
    rows = []
    cells = {}
    kernels_before = kops.kernels_enabled()

    def variants_for(impl):
        out = [("per_consumer", None, 1, False)]
        if "pad" in packs:
            out.append(("unified_pad", "pad", 1, False))
            # fold-aware pad: the baseline layout on the same u32/u64 lanes,
            # isolating the packing effect from the lane width
            for fold in folds:
                if fold > 1:
                    out.append((f"unified_pad_fold{fold}", "pad", fold,
                                False))
        if "packed" in packs:
            # headline cell: the default fabric config (word_fold="auto")
            out.append(("unified_packed", "packed", "auto", False))
            for fold in folds:
                out.append((f"unified_packed_fold{fold}", "packed", fold,
                            False))
            if impl == "medusa":       # crossbar bursts never kernelize
                out.append(("unified_packed_kernel", "packed", 1, True))
                if 2 in folds:
                    out.append(("unified_packed_fold2_kernel", "packed", 2,
                                True))
        return out

    try:
        for impl in ("medusa", "crossbar"):
            kops.use_kernels(False)
            ref = _fns(impl, "packed")[0](*args)
            for name, pack, fold, kern in variants_for(impl):
                kops.use_kernels(kern)
                per, uni = _fns(impl, pack or "packed", fold)
                fn = per if pack is None else uni
                for x, y in zip(ref, fn(*args)):
                    assert np.array_equal(np.asarray(x, np.float32),
                                          np.asarray(y, np.float32)), (impl,
                                                                       name)
                census = hlo_op_census(fn, *args)
                gathers = (census.get("gather", 0)
                           + census.get("dynamic-slice", 0)
                           + census.get("scatter", 0))
                cell = {"us": time_us(fn, *args, iters=50),
                        "total_hlo_ops": sum(census.values()),
                        "gather_ops": gathers}
                if pack is not None:
                    stats = _word_census(impl, pack, fold, args)
                    cell["network_calls"] = stats.network_calls
                    cell["words_moved"] = stats.words_moved
                    cell["words_padded"] = stats.words_padded
                    cell["words_folded"] = stats.words_folded
                    cell["kernel_bursts"] = stats.kernel_bursts
                else:
                    cell["network_calls"] = 4
                    cell["words_moved"] = sum(
                        int(np.prod(a.shape)) for a in args)
                    cell["words_padded"] = 0
                    cell["words_folded"] = 0
                    cell["kernel_bursts"] = 0
                cells[f"{impl}/{name}"] = cell
                for key, val in cell.items():
                    rows.append((f"fabric_unified/{impl}/{name}/{key}",
                                 val if key == "us" else None,
                                 "" if key == "us" else val))
        paged_decode_cells(cells, rows)
        sharded_decode_cells(cells, rows)
        moe_dispatch_cells(cells, rows)
        spec_decode_cells(cells, rows)
    finally:
        kops.use_kernels(kernels_before)

    run_record = {
        "git_sha": _git_sha(),
        "date": datetime.datetime.now(datetime.timezone.utc).isoformat(),
        "hostname": socket.gethostname(),
        "jax": jax.__version__,
        "workload": {"n_ports": N, "streams": 4, "words": [D, 32, 16, 1],
                     "dtype": "bfloat16"},
        "axes": {"packs": list(packs), "folds": list(folds),
                 "x64": bool(jax.config.read("jax_enable_x64"))},
        "cells": cells,
    }
    path = os.path.join(os.environ.get("BENCH_DIR", "."), "BENCH_fabric.json")
    _append_run(path, run_record)

    m, c = cells.get("medusa/unified_packed"), cells.get(
        "crossbar/unified_packed")
    if m and c:
        print(f"# medusa/crossbar unified_packed wall-clock: "
              f"{m['us']:.0f}us / {c['us']:.0f}us = {m['us'] / c['us']:.2f}x")
    mk = cells.get("medusa/unified_packed_kernel")
    if m and mk:
        print(f"# medusa fused-kernel burst HLO ops: "
              f"{mk['total_hlo_ops']} (unrolled {m['total_hlo_ops']})")
    ga = cells.get("medusa/decode_gather_after_occ25")
    fu = cells.get("medusa/decode_fused_occ25")
    if ga and fu:
        print(f"# medusa paged decode @25% occupancy: fused "
              f"{fu['us']:.0f}us / {fu['words_moved']} words vs "
              f"gather-after {ga['us']:.0f}us / {ga['words_moved']} words")
    s1 = cells.get("medusa/decode_sharded_1dev")
    s8 = cells.get(f"medusa/decode_sharded_{max(SHARD_COUNTS)}dev")
    if s1 and s8:
        print(f"# sharded pool decode at {s8['pool_shards']} shards: "
              f"{s8['words_cross_shard']} of {s8['words_moved']} words "
              f"crossed shards "
              f"({s8['words_cross_shard'] / s8['words_moved']:.0%}, "
              f"{s8['words_local']} stayed local); wall {s1['us']:.0f}us "
              f"(1dev) -> {s8['us']:.0f}us "
              f"({s8['pool_shards']}dev, host devices)")
    mr = cells.get("medusa/moe_dispatch_route")
    mb = cells.get("medusa/moe_dispatch_burst")
    if mr and mb:
        print(f"# medusa moe dispatch: burst {mb['us']:.0f}us / "
              f"{mb['words_moved']} words vs route {mr['us']:.0f}us; "
              f"{mb['tokens_dropped']} assignments dropped at capacity")
    k0 = cells.get("medusa/decode_spec_dense")
    k2 = cells.get("medusa/decode_spec_k2")
    if k0 and k2:
        print(f"# spec decode step: k=2 draft rows {k2['us']:.0f}us vs "
              f"dense {k0['us']:.0f}us")
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--pack", choices=["packed", "pad", "both"],
                    default="both", help="burst layout(s) to A/B")
    ap.add_argument("--fold", type=int, nargs="*", default=None,
                    choices=[1, 2, 4],
                    help="word_fold factors to sweep (default: 1 2, plus 4 "
                         "under x64)")
    ap.add_argument("--sharded-json", action="store_true",
                    help="run only the decode_sharded_* cells and print "
                         "them as JSON (the forced-device-count subprocess "
                         "re-exec; no BENCH_fabric.json append)")
    a = ap.parse_args()
    if a.sharded_json:
        print(_SHARDED_MARK + json.dumps(_sharded_cells()))
        sys.exit(0)
    folds = tuple(a.fold) if a.fold else (
        (1, 2, 4) if jax.config.read("jax_enable_x64") else (1, 2))
    emit(run(("packed", "pad") if a.pack == "both" else (a.pack,), folds))
