"""Unified burst-scheduled fabric vs per-consumer interconnect calls.

The refactor claim measured: before, every consumer (KV read, weight
stream, MoE dispatch staging, host batch staging) ran its own
``Interconnect`` call — one read-network lowering each.  After, the
:class:`repro.fabric.BurstScheduler` concatenates all queued streams and
invokes the shared network once per dtype.  We lower both forms over the
same traffic and compare total HLO ops, gather census, and CPU wall time,
for the medusa and crossbar fabrics.

Semantics are asserted identical before measuring.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.pipeline import batch_lines
from repro.fabric import BurstScheduler, Fabric
from benchmarks.common import emit, time_us, hlo_op_census

N = 8            # ports
D = 64           # KV head_dim (lane width of the kv stream)


def _traffic():
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 3)
    kv = jax.random.normal(ks[0], (16 * N, N, D), jnp.bfloat16)
    wt = jax.random.normal(ks[1], (8 * N, N, 32), jnp.bfloat16)
    moe = jax.random.normal(ks[2], (4 * N, N, 16), jnp.bfloat16)
    toks = np.arange(4 * 128, dtype=np.int32).reshape(4, 128) % 997
    stage = jnp.asarray(batch_lines(toks, N), jnp.bfloat16)
    return kv, wt, moe, stage


def _fns(impl: str):
    fab = Fabric.make(N, impl)

    def per_consumer(kv, wt, moe, stage):
        # seed style: one network call per consumer
        return (fab.read(kv), fab.read(wt), fab.read(moe), fab.read(stage))

    def unified(kv, wt, moe, stage):
        sched = BurstScheduler(fab)
        sched.enqueue_read("kv_read", kv)
        sched.enqueue_read("weight_stream", wt)
        sched.enqueue_read("moe_dispatch", moe)
        sched.enqueue_read("batch_stage", stage)
        out = sched.flush()
        return (out["kv_read"], out["weight_stream"], out["moe_dispatch"],
                out["batch_stage"])

    return jax.jit(per_consumer), jax.jit(unified)


def run() -> list:
    args = _traffic()
    rows = []
    for impl in ("medusa", "crossbar"):
        per, uni = _fns(impl)
        a, b = per(*args), uni(*args)
        for x, y in zip(a, b):
            assert np.array_equal(np.asarray(x, np.float32),
                                  np.asarray(y, np.float32))
        for name, fn in (("per_consumer", per), ("unified", uni)):
            census = hlo_op_census(fn, *args)
            gathers = (census.get("gather", 0) + census.get("dynamic-slice", 0)
                       + census.get("scatter", 0))
            rows.append((f"fabric_unified/{impl}/{name}/us",
                         time_us(fn, *args), ""))
            rows.append((f"fabric_unified/{impl}/{name}/total_hlo_ops", None,
                         sum(census.values())))
            rows.append((f"fabric_unified/{impl}/{name}/gather_ops", None,
                         gathers))
    return rows


if __name__ == "__main__":
    emit(run())
