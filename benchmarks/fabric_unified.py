"""Unified burst-scheduled fabric vs per-consumer interconnect calls.

The refactor claim measured: before, every consumer (KV read, weight
stream, MoE dispatch staging, host batch staging) ran its own
``Interconnect`` call — one read-network lowering each.  After, the
:class:`repro.fabric.BurstScheduler` merges all queued streams and invokes
the shared network once per dtype.  Two burst layouts are A/B'd on the same
4-stream mixed-width traffic:

* ``packed`` (default) — streams fold their line groups into the word axis
  and concatenate along words: the network moves zero padding;
* ``pad`` — pad-to-widest line-axis concatenation (PR 1's layout, kept as
  the fallback that shows why packing matters: the padded words it moves
  cost real wall-clock).

We lower all forms over the same traffic and compare total HLO ops, gather
census, CPU wall time, and words moved vs padded, for the medusa and
crossbar fabrics.  Semantics are asserted identical before measuring, and
the unified forms run through the issue()/commit() pipeline.  Results also
land in ``BENCH_fabric.json`` (dir from ``$BENCH_DIR``, default cwd) — the
perf-trajectory artifact.

    python -m benchmarks.fabric_unified [--pack {packed,pad,both}]
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.pipeline import batch_lines
from repro.fabric import BurstScheduler, Fabric, SchedulerStats
from benchmarks.common import emit, time_us, hlo_op_census

N = 8            # ports
D = 64           # KV head_dim (lane width of the kv stream)


def _traffic():
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 3)
    kv = jax.random.normal(ks[0], (16 * N, N, D), jnp.bfloat16)
    wt = jax.random.normal(ks[1], (8 * N, N, 32), jnp.bfloat16)
    moe = jax.random.normal(ks[2], (4 * N, N, 16), jnp.bfloat16)
    toks = np.arange(4 * 128, dtype=np.int32).reshape(4, 128) % 997
    stage = jnp.asarray(batch_lines(toks, N), jnp.bfloat16)
    return kv, wt, moe, stage


def _enqueue_all(sched, kv, wt, moe, stage):
    sched.enqueue_read("kv_read", kv)
    sched.enqueue_read("weight_stream", wt)
    sched.enqueue_read("moe_dispatch", moe)
    sched.enqueue_read("batch_stage", stage)


def _fns(impl: str, pack: str):
    fab = Fabric.make(N, impl, pack=pack)

    def per_consumer(kv, wt, moe, stage):
        # seed style: one network call per consumer
        return (fab.read(kv), fab.read(wt), fab.read(moe), fab.read(stage))

    def unified(kv, wt, moe, stage):
        sched = BurstScheduler(fab)
        _enqueue_all(sched, kv, wt, moe, stage)
        sched.issue()                      # transfer overlaps consumer compute
        out = sched.commit()
        return (out["kv_read"], out["weight_stream"], out["moe_dispatch"],
                out["batch_stage"])

    return jax.jit(per_consumer), jax.jit(unified)


def _word_census(impl: str, pack: str, args) -> SchedulerStats:
    stats = SchedulerStats()
    sched = BurstScheduler(Fabric.make(N, impl, pack=pack), stats=stats)
    _enqueue_all(sched, *args)
    sched.flush()
    return stats


def run(packs=("packed", "pad")) -> list:
    args = _traffic()
    rows = []
    artifact = {"workload": {"n_ports": N, "streams": 4,
                             "words": [D, 32, 16, 1], "dtype": "bfloat16"}}
    for impl in ("medusa", "crossbar"):
        variants = []
        per, _ = _fns(impl, "packed")
        variants.append(("per_consumer", per, None))
        for pack in packs:
            _, uni = _fns(impl, pack)
            variants.append((f"unified_{pack}", uni, pack))
        ref = variants[0][1](*args)
        for name, fn, pack in variants:
            for x, y in zip(ref, fn(*args)):
                assert np.array_equal(np.asarray(x, np.float32),
                                      np.asarray(y, np.float32))
            census = hlo_op_census(fn, *args)
            gathers = (census.get("gather", 0) + census.get("dynamic-slice", 0)
                       + census.get("scatter", 0))
            cell = {"us": time_us(fn, *args),
                    "total_hlo_ops": sum(census.values()),
                    "gather_ops": gathers}
            if pack is not None:
                stats = _word_census(impl, pack, args)
                cell["network_calls"] = stats.network_calls
                cell["words_moved"] = stats.words_moved
                cell["words_padded"] = stats.words_padded
            else:
                cell["network_calls"] = 4
                cell["words_moved"] = sum(
                    int(np.prod(a.shape)) for a in args)
                cell["words_padded"] = 0
            artifact[f"{impl}/{name}"] = cell
            for key, val in cell.items():
                rows.append((f"fabric_unified/{impl}/{name}/{key}",
                             val if key == "us" else None,
                             "" if key == "us" else val))
    path = os.path.join(os.environ.get("BENCH_DIR", "."), "BENCH_fabric.json")
    with open(path, "w") as f:
        json.dump(artifact, f, indent=2, sort_keys=True)
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--pack", choices=["packed", "pad", "both"],
                    default="both", help="burst layout(s) to A/B")
    a = ap.parse_args()
    emit(run(("packed", "pad") if a.pack == "both" else (a.pack,)))
