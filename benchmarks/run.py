"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV.  Sub-benchmarks:
  table_complexity       — §II-B/III-D mux-count model (paper-claim validation)
  table1_baseline_vs_axis — Table I analogue (baseline fairness)
  table2_resource        — Table II analogue (medusa vs crossbar networks)
  fig6_scalability       — Fig. 6 analogue (scaling sweep N=8..64)
  kv_layout              — production KV-cache path, per-fabric
  fabric_unified         — burst-scheduled fabric vs per-consumer calls
  moe_dispatch           — medusa ring vs XLA all-to-all (multi-device)
  roofline               — dry-run roofline table (if results exist)
"""

from __future__ import annotations

import sys
import traceback

from benchmarks.common import emit


def main() -> None:
    mods = ["table_complexity", "table1_baseline_vs_axis", "table2_resource",
            "fig6_scalability", "kv_layout", "fabric_unified", "moe_dispatch",
            "roofline"]
    only = [a for a in sys.argv[1:] if not a.startswith("-")]
    failures = 0
    print("name,us_per_call,derived")
    for name in mods:
        if only and name not in only:
            continue
        try:
            mod = __import__(f"benchmarks.{name}", fromlist=["run"])
            emit(mod.run())
        except Exception as e:
            failures += 1
            print(f"{name},,{e!r}")
            traceback.print_exc(file=sys.stderr)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
