import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
os.environ.setdefault("REPRO_NO_KERNELS", "1")

"""§Perf hillclimbing driver: per-cell variants, lowered and analysed like the
dry-run, written to results/perf/<cell>__<variant>.json.

The three chosen cells (worst roofline, most collective-bound, most paper-
representative) each get a sequence of hypothesis-driven variants; the
baseline (= the paper-faithful configuration already recorded by the dry-run)
is re-recorded here as variant "baseline" for side-by-side comparison.

Usage: PYTHONPATH=src:. python benchmarks/hillclimb.py [--cell NAME]
"""

import argparse
import json
import time
import traceback

VARIANTS = {
    # --- Cell C: starcoder2-15b x decode_32k (paper-representative KV path) —
    "starcoder2-15b__decode_32k": [
        ("baseline", {}),                     # medusa layout (oracle lowering)
        # H1: the materialised port-major cache copy doubles HBM traffic per
        # layer; contract directly on the line-major cache.
        ("fused_kv", {"kv_layout": "fused"}),
        # H2: kv_heads=4 cannot split model=16 → cache replicated 16x; shard
        # the cache time axis instead (sequence-parallel decode).
        ("fused_kv+sp", {"kv_layout": "fused", "sharding_profile": "sp_seq"}),
        # H3: weights also streamed over the data axis (inference FSDP).
        ("fused_kv+sp+fsdp", {"kv_layout": "fused",
                              "sharding_profile": "sp_seq",
                              "serve_fsdp": True}),
    ],
    # --- Cell B: granite-moe-3b x train_4k (most collective-bound) ---------
    "granite-moe-3b-a800m__train_4k": [
        ("baseline", {}),                     # moe_cap profile, 40 experts
        # H1: 40 experts cannot split model=16 → weights replicated and the
        # capacity-dim sharding forces per-layer allgathers.  Pad to 48 dead
        # experts (never routed) so EP divides: experts 3/chip.
        ("pad48_ep", {"moe": ("pad_to", 48), "sharding_profile": "sp_seq"}),
        # H2: keep padded EP but heads-TP attention (tp_heads drops the
        # non-divisible head constraint → replicated attention activations).
        ("pad48_tp", {"moe": ("pad_to", 48), "sharding_profile": "tp_heads"}),
        # H3: dispatch buffers [E, C, d] should shard C over data as well —
        # 2-D expert parallelism keeps per-chip buffers ~E/16 x C/16.
        ("pad48_ep2d", {"moe": ("pad_to", 48), "sharding_profile": "ep_2d"}),
        # H4 (code change, applies to all variants after it): dispatch moves
        # payload by gather only; scatters touch 4-byte indices.  Re-measure
        # the two best shardings under the gather dispatch.
        ("gatherdisp_ep", {"moe": ("pad_to", 48), "sharding_profile": "sp_seq"}),
        ("gatherdisp_ep2d", {"moe": ("pad_to", 48), "sharding_profile": "ep_2d"}),
    ],
    # --- Cell A: kimi-k2 x decode_32k (worst absolute memory term) ---------
    "kimi-k2-1t-a32b__decode_32k": [
        ("baseline", {}),
        # H1: 2TB bf16 weights / 16-way model sharding = 125GB/chip; serving
        # needs no DP weight replication — shard over data too (16x less).
        ("serve_fsdp", {"serve_fsdp": True}),
        # H2: kv_heads=8 %16 → cache replicated over model; shard cache time
        # axis (sp) and fuse the layout read.
        ("fsdp+sp+fused", {"serve_fsdp": True, "sharding_profile": "sp_seq",
                           "kv_layout": "fused"}),
        # H3: 2-D EP for the expert weights at decode too.
        ("fsdp+ep2d+fused", {"serve_fsdp": True, "sharding_profile": "ep_2d",
                             "kv_layout": "fused"}),
    ],
}

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results", "perf")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", default=None)
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()
    os.makedirs(RESULTS, exist_ok=True)

    import dataclasses
    from repro.launch.dryrun import run_cell
    from repro.configs import get_config

    for cell, variants in VARIANTS.items():
        if args.cell and args.cell != cell:
            continue
        arch, shape = cell.split("__")
        for vname, overrides in variants:
            path = os.path.join(RESULTS, f"{cell}__{vname}.json")
            if os.path.exists(path) and not args.force:
                print(f"cached: {cell} {vname}")
                continue
            ov = dict(overrides)
            if "moe" in ov:
                field, val = ov.pop("moe")
                cfg0 = get_config(arch)
                ov["moe"] = dataclasses.replace(cfg0.moe, **{field: val})
            print(f"=== {cell} [{vname}] {overrides}", flush=True)
            t0 = time.time()
            try:
                res = run_cell(arch, shape, multi_pod=False, overrides=ov)
                res["variant"] = vname
                r = res["roofline"]
                print(f"    compute={r['compute_s']:.3e} "
                      f"memory={r['memory_s']:.3e} "
                      f"coll={r['collective_s']:.3e} dom={r['dominant']} "
                      f"temp={res['memory']['temp_bytes']/1e9:.1f}GB "
                      f"({time.time()-t0:.0f}s)", flush=True)
            except Exception as e:
                res = {"arch": arch, "shape": shape, "variant": vname,
                       "status": "error", "error": repr(e),
                       "traceback": traceback.format_exc()}
                print(f"    ERROR {e!r}", flush=True)
            with open(path, "w") as f:
                json.dump(res, f, indent=1, default=str)


if __name__ == "__main__":
    main()
