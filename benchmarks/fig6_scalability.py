"""Paper Fig. 6 analogue: scalability as the interconnect grows.

The paper scales the accelerator (DSPs) and interface width (128→1024 bit)
and finds the baseline's frequency collapses (<25 MHz at 1024-bit) while
Medusa holds 200-225 MHz.  On TPU the frequency race becomes: how do wall
time, data movement and op counts of the two fabrics scale with N?  The
crossbar's gather cost grows O(N) per word; Medusa's roll/select network
grows O(log N) per word — we sweep N = 8..64 (interface 128→1024 "bits")
and report the measured ratio (the "frequency gain" analogue).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (read_network_medusa, read_network_crossbar,
                        read_network_oracle, medusa_mux_count,
                        baseline_mux_count)
from benchmarks.common import emit, time_us, bytes_accessed

W_ACC = 16
GROUPS = 16


def run() -> list:
    rows = []
    key = jax.random.PRNGKey(0)
    for n in (8, 16, 32, 64):
        lines = jax.random.normal(key, (GROUPS * n, n, W_ACC),
                                  dtype=jnp.bfloat16)
        ref = read_network_oracle(lines, n)
        med = jax.jit(lambda x, n=n: read_network_medusa(x, n))
        cbar = jax.jit(lambda x, n=n: read_network_crossbar(x, n))
        assert np.allclose(np.asarray(med(lines), np.float32),
                           np.asarray(ref, np.float32))
        assert np.allclose(np.asarray(cbar(lines), np.float32),
                           np.asarray(ref, np.float32))
        t_med = time_us(med, lines)
        t_cbar = time_us(cbar, lines)
        w_line = n * W_ACC
        rows.append((f"fig6/W{w_line}_N{n}/medusa_us", t_med, ""))
        rows.append((f"fig6/W{w_line}_N{n}/crossbar_us", t_cbar, ""))
        rows.append((f"fig6/W{w_line}_N{n}/speedup", None,
                     f"{t_cbar / t_med:.2f}x"))
        rows.append((f"fig6/W{w_line}_N{n}/mux_ratio_model", None,
                     f"{baseline_mux_count(w_line, n) / medusa_mux_count(w_line, n):.2f}x"))
    return rows


if __name__ == "__main__":
    emit(run())
