"""Paper Table I analogue: validating the baseline is itself efficient.

The paper compares its hand-built baseline against Xilinx AXI4-Stream IP to
show the baseline is a *fair* reference (Table I: the IP cores cost ~2-5x
more).  Our analogue: the gather-based crossbar baseline vs a deliberately
naive "IP-style" network that routes through one-hot matmuls (the laziest
correct implementation — dense select of every word for every output slot).
Configuration mirrors Table I: 256-bit line → 16 x 16-bit ports.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import read_network_crossbar, read_network_oracle
from benchmarks.common import emit, time_us, bytes_accessed, flops_of

N = 16
W = 16
GROUPS = 32


def read_network_onehot(lines: jax.Array, n_ports: int) -> jax.Array:
    """AXI-IP-style naive network: one-hot matmul routing (full crossbar,
    every output slot selects among all N*N words of its group)."""
    n = n_ports
    groups = lines.shape[0] // n
    tiles = lines.reshape(groups, n * n, W)
    # routing matrix [out_slot, in_word]: out (y, p) ← in (p, y)
    y = jnp.arange(n * n) // n
    p = jnp.arange(n * n) % n
    route = jax.nn.one_hot(p * n + y, n * n, dtype=lines.dtype)
    out = jnp.einsum("oi,giw->gow", route, tiles)
    return out.reshape(groups, n, n, W)


def run() -> list:
    key = jax.random.PRNGKey(0)
    lines = jax.random.normal(key, (GROUPS * N, N, W), dtype=jnp.bfloat16)
    ref = read_network_oracle(lines, N)
    base = jax.jit(lambda x: read_network_crossbar(x, N))
    naive = jax.jit(lambda x: read_network_onehot(x, N))
    assert np.allclose(np.asarray(base(lines), np.float32),
                       np.asarray(ref, np.float32))
    assert np.allclose(np.asarray(naive(lines), np.float32),
                       np.asarray(ref, np.float32))
    rows = []
    for name, fn in (("baseline_crossbar", base), ("axi_style_onehot", naive)):
        rows.append((f"table1/{name}/us", time_us(fn, lines), ""))
        rows.append((f"table1/{name}/bytes", None,
                     int(bytes_accessed(lambda x: fn(x), lines))))
        rows.append((f"table1/{name}/flops", None,
                     int(flops_of(lambda x: fn(x), lines))))
    return rows


if __name__ == "__main__":
    emit(run())
