"""Production-path benchmark: the KV-cache layout engine (medusa vs crossbar
vs oracle) inside a real decode-attention computation.

This is the paper's technique where it actually lives in the framework: the
serve_step reads the line-major KV cache through the interconnect.  We time
a full decode attention (batch x heads x 32k cache) under each fabric and
census the lowered HLO.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke
from repro.fabric import Fabric
from repro.models import common as cm
from benchmarks.common import emit, time_us, hlo_op_census

B, T, HKV, D = 4, 4096, 4, 64


def _attn(kv_layout: str):
    cfg = dataclasses.replace(get_smoke("starcoder2-15b"),
                              kv_layout=kv_layout, n_kv_heads=HKV,
                              n_heads=HKV * 2, head_dim=D)
    fabric = Fabric.for_model(cfg)

    def f(q, ck, cv, pos):
        ck_p = fabric.kv_port_major(ck)
        cv_p = fabric.kv_port_major(cv)
        kv_pos = jnp.arange(T)
        return cm._decode_attention(q, ck_p, cv_p, pos, kv_pos,
                                    kv_pos <= pos, 0)
    return jax.jit(f)


def run() -> list:
    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (B, 1, HKV * 2, D), jnp.bfloat16)
    ck = jax.random.normal(key, (B, T, HKV, D), jnp.bfloat16)
    cv = jax.random.normal(key, (B, T, HKV, D), jnp.bfloat16)
    pos = jnp.int32(T - 1)

    outs = {}
    rows = []
    # Fabric comparison under XLA lowering (the Pallas kernel's interpret
    # mode is a Python-level correctness vehicle, not a timing vehicle — the
    # kernel suite sweeps it separately in tests/test_kernels.py).
    from repro.kernels import ops as kops
    was = kops.kernels_enabled()
    kops.use_kernels(False)
    try:
        for layout in ("oracle", "crossbar", "medusa", "fused"):
            fn = _attn(layout)
            outs[layout] = np.asarray(fn(q, ck, cv, pos), np.float32)
            census = hlo_op_census(fn, q, ck, cv, pos)
            rows.append((f"kv_layout/{layout}/us",
                         time_us(fn, q, ck, cv, pos), ""))
            rows.append((f"kv_layout/{layout}/gather_ops", None,
                         census.get("gather", 0)
                         + census.get("dynamic-slice", 0)))
    finally:
        kops.use_kernels(was)
    assert np.allclose(outs["oracle"], outs["crossbar"], atol=1e-3)
    assert np.allclose(outs["oracle"], outs["medusa"], atol=1e-3)
    assert np.allclose(outs["oracle"], outs["fused"], atol=1e-3)
    return rows


if __name__ == "__main__":
    emit(run())
