"""Paper Table II analogue: Medusa vs crossbar data-transfer networks on TPU.

FPGA LUT/FF counts have no TPU meaning; the resource contrast becomes the
*lowered HLO*: the crossbar routing materialises gather ops and index tensors,
Medusa lowers to static slice/concat/select chains that fuse.  At the paper
design point (512-bit line = 32 ports x 16-bit; we map bit→bf16 element) we
measure, for read and write networks separately:

* gather-op count and total HLO ops (the "logic" census),
* bytes accessed (cost_analysis) — the wiring/data-movement analogue,
* median CPU wall time per call (relative, same host→ same units).

Identical semantics are asserted before measuring.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (read_network_medusa, read_network_crossbar,
                        read_network_oracle, write_network_medusa,
                        write_network_crossbar)
from benchmarks.common import emit, time_us, hlo_op_census, bytes_accessed

N_PORTS = 32
W_ACC = 16          # "16-bit word" → 16 bf16 elements per word
GROUPS = 32         # 32-line burst per port (paper MaxBurst)


def _lines():
    key = jax.random.PRNGKey(0)
    return jax.random.normal(key, (GROUPS * N_PORTS, N_PORTS, W_ACC),
                             dtype=jnp.bfloat16)


def run() -> list:
    lines = _lines()
    banked_ref = read_network_oracle(lines, N_PORTS)

    med = jax.jit(lambda x: read_network_medusa(x, N_PORTS))
    cbar = jax.jit(lambda x: read_network_crossbar(x, N_PORTS))
    assert np.allclose(np.asarray(med(lines), np.float32),
                       np.asarray(banked_ref, np.float32))
    assert np.allclose(np.asarray(cbar(lines), np.float32),
                       np.asarray(banked_ref, np.float32))

    wmed = jax.jit(lambda b: write_network_medusa(b, N_PORTS))
    wcbar = jax.jit(lambda b: write_network_crossbar(b, N_PORTS))
    assert np.allclose(np.asarray(wmed(banked_ref), np.float32),
                       np.asarray(lines, np.float32))
    assert np.allclose(np.asarray(wcbar(banked_ref), np.float32),
                       np.asarray(lines, np.float32))

    rows = []
    for name, fn, arg in (
            ("read/medusa", med, lines), ("read/crossbar", cbar, lines),
            ("write/medusa", wmed, banked_ref),
            ("write/crossbar", wcbar, banked_ref)):
        census = hlo_op_census(lambda x: fn(x), arg)
        gathers = census.get("gather", 0) + census.get("dynamic-slice", 0) \
            + census.get("scatter", 0)
        by = bytes_accessed(lambda x: fn(x), arg)
        us = time_us(fn, arg)
        rows.append((f"table2/{name}/us", us, ""))
        rows.append((f"table2/{name}/gather_ops", None, gathers))
        rows.append((f"table2/{name}/total_hlo_ops", None,
                     sum(census.values())))
        rows.append((f"table2/{name}/bytes_accessed", None, int(by)))
    return rows


if __name__ == "__main__":
    emit(run())
